//! Adaptive-respecialization scenarios (ISSUE 3 satellite):
//!   A1  a workload whose trip count shifts mid-run triggers *exactly
//!       one* respecialization, and outputs are bit-identical before and
//!       after the in-place stub swap;
//!   A2  a workload where the specialized artifact models slower rolls
//!       back to the generic tier within one decision window;
//!   A3  profile rows are snapshot/reset at call-table patch time, so
//!       the monitor only ever sees post-patch data (regression test for
//!       the pre-offload-sample pollution bug);
//!   A4  with the background compile service on, both the
//!       interpreter→generic promotion and the generic→specialized
//!       respecialization defer their P&R: the function keeps executing
//!       its current tier, the swap fires at a later decision window as a
//!       cache hit, numerics stay exact, and the manager records zero
//!       compile stall.

use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::Ty;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::offload::adapt::{AdaptController, AdaptParams, Tier};
use tlo::offload::{OffloadManager, OffloadParams};
use tlo::profile::Monitor;

/// Elementwise kernel: C[i] = A[i] + 3*B[i] + 1 (the Fig-2 shape).
fn fig2_module() -> Module {
    let mut m = Module::new();
    let mut b = FuncBuilder::new(
        "fig2",
        &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
    );
    let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let av = b.load(Ty::I32, a, i);
        let bv = b.load(Ty::I32, bb, i);
        let c3 = b.const_i32(3);
        let t = b.mul(bv, c3);
        let s = b.add(av, t);
        let c1 = b.const_i32(1);
        let r = b.add(s, c1);
        b.store(Ty::I32, c, i, r);
    });
    m.add(b.ret(None));
    m
}

/// Reduction kernel: acc[0] += A[i] * B[i] — unrolling chains the partial
/// adds inside the fabric, so the specialized artifact is strictly deeper
/// than the generic one (the demotion test relies on that).
fn dot_module() -> Module {
    let mut m = Module::new();
    let mut b = FuncBuilder::new(
        "dot",
        &[("acc", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
    );
    let (acc, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        let cur = b.load(Ty::I32, acc, z);
        let x = b.load(Ty::I32, a, i);
        let y = b.load(Ty::I32, bb, i);
        let p = b.mul(x, y);
        let s = b.add(cur, p);
        let z2 = b.const_i32(0);
        b.store(Ty::I32, acc, z2, s);
    });
    m.add(b.ret(None));
    m
}

#[test]
fn a1_trip_count_shift_triggers_exactly_one_respecialization() {
    let mut engine = Engine::new(fig2_module()).unwrap();
    let mut mem = Memory::new();
    let cap = 512usize;
    let a: Vec<i32> = (0..cap as i32).map(|i| i * 7 - 300).collect();
    let b: Vec<i32> = (0..cap as i32).map(|i| 11 - i).collect();
    let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
    let hc = mem.alloc_i32(cap);
    let func = engine.func_index("fig2").unwrap();

    let mut mgr =
        OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
    let mut ctl = AdaptController::new(AdaptParams {
        hot_cycles: 1,
        hot_invocations: 1,
        generic_unroll: 1,
        candidate_unrolls: vec![4],
        min_lanes: 4,
        min_batch: 1,
        decision_window: 2,
    });

    let mut run = |engine: &mut Engine, mem: &mut Memory, n: usize| {
        mem.i32s_mut(hc).fill(0);
        engine
            .call_idx(func, mem, &[Val::P(hc), Val::P(ha), Val::P(hb), Val::I(n as i32)])
            .unwrap();
        for i in 0..n {
            assert_eq!(mem.i32s(hc)[i], a[i] + 3 * b[i] + 1, "element {i} at n={n}");
        }
    };

    // Phase 1: small batches (8/4 = 2 lanes < min_lanes) — promotes to
    // the generic tier but never specializes.
    for _ in 0..4 {
        run(&mut engine, &mut mem, 8);
        ctl.observe(&mut mgr, &mut engine, func);
    }
    assert_eq!(ctl.tier(func), Tier::Generic);
    assert_eq!(ctl.respecializations(func), 0);
    assert!(engine.is_patched(func));

    // Phase 2: the trip count shifts up mid-run (509 is odd: the u=4
    // artifact exercises the host remainder). Exactly one
    // Generic→Specialized swap may fire, outputs identical before/after.
    for _ in 0..6 {
        run(&mut engine, &mut mem, 509);
        ctl.observe(&mut mgr, &mut engine, func);
    }
    assert_eq!(ctl.tier(func), Tier::Specialized);
    assert_eq!(ctl.unroll(func), 4);
    assert_eq!(ctl.respecializations(func), 1, "{:?}", ctl.transitions(func));
    let to_spec = ctl
        .transitions(func)
        .iter()
        .filter(|t| t.to == Tier::Specialized)
        .count();
    assert_eq!(to_spec, 1, "exactly one respecialization: {:?}", ctl.transitions(func));
    // The manager really swapped the artifact (specialization signature).
    let active = mgr.active(func).expect("live artifact");
    assert_eq!(active.unroll, 4);
    assert!(active.sig.trip_bucket > 0, "specialized artifacts carry the trip bucket");

    // Stability: more invocations at the same regime change nothing.
    for _ in 0..4 {
        run(&mut engine, &mut mem, 509);
        ctl.observe(&mut mgr, &mut engine, func);
    }
    assert_eq!(ctl.respecializations(func), 1);
}

#[test]
fn a2_slower_specialized_artifact_demotes_to_generic_within_one_window() {
    let mut engine = Engine::new(dot_module()).unwrap();
    let mut mem = Memory::new();
    let cap = 64usize;
    let a: Vec<i32> = (0..cap as i32).map(|i| i % 9 - 4).collect();
    let b: Vec<i32> = (0..cap as i32).map(|i| i % 7 - 3).collect();
    let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
    let hacc = mem.alloc_i32(1);
    let func = engine.func_index("dot").unwrap();

    let mut mgr =
        OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
    let mut ctl = AdaptController::new(AdaptParams {
        hot_cycles: 1,
        hot_invocations: 1,
        generic_unroll: 1,
        candidate_unrolls: vec![4],
        min_lanes: 4,
        min_batch: 1,
        decision_window: 1,
    });

    let mut want_acc = 0i32;
    let mut run = |engine: &mut Engine, mem: &mut Memory, want: &mut i32, n: usize| {
        engine
            .call_idx(func, mem, &[Val::P(hacc), Val::P(ha), Val::P(hb), Val::I(n as i32)])
            .unwrap();
        for i in 0..n {
            *want = want.wrapping_add(a[i].wrapping_mul(b[i]));
        }
        assert_eq!(mem.i32s(hacc)[0], *want, "accumulator at n={n}");
    };

    // Specialize on big batches.
    for _ in 0..3 {
        run(&mut engine, &mut mem, &mut want_acc, 64);
        ctl.observe(&mut mgr, &mut engine, func);
    }
    assert_eq!(ctl.tier(func), Tier::Specialized, "{:?}", ctl.transitions(func));
    assert_eq!(ctl.unroll(func), 4);

    // The workload collapses to tiny batches: at batch=2 the specialized
    // pipeline's deeper fill models strictly slower than the generic
    // artifact, so the controller must demote within one window.
    run(&mut engine, &mut mem, &mut want_acc, 2);
    ctl.observe(&mut mgr, &mut engine, func);
    assert_eq!(
        ctl.tier(func),
        Tier::Generic,
        "demotion within one window: {:?}",
        ctl.transitions(func)
    );
    assert_eq!(ctl.unroll(func), 1);
    let last = *ctl.transitions(func).last().unwrap();
    assert_eq!((last.from, last.to), (Tier::Specialized, Tier::Generic));
    // Demotion is a cache hit (the generic artifact was retained), and
    // the function never left the offloaded path.
    assert!(engine.is_patched(func));
    // Numerics keep flowing correctly after the demotion swap.
    for _ in 0..3 {
        run(&mut engine, &mut mem, &mut want_acc, 2);
        ctl.observe(&mut mgr, &mut engine, func);
    }
}

#[test]
fn a4_compile_service_defers_promotion_and_respec_without_stalls() {
    let mut engine = Engine::new(fig2_module()).unwrap();
    let mut mem = Memory::new();
    let cap = 512usize;
    let a: Vec<i32> = (0..cap as i32).map(|i| i * 5 - 99).collect();
    let b: Vec<i32> = (0..cap as i32).map(|i| 23 - i).collect();
    let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
    let hc = mem.alloc_i32(cap);
    let func = engine.func_index("fig2").unwrap();

    let mut mgr = OffloadManager::new(OffloadParams {
        min_dfg_nodes: 1,
        compile_threads: 2,
        portfolio: 2,
        ..Default::default()
    });
    let mut ctl = AdaptController::new(AdaptParams {
        hot_cycles: 1,
        hot_invocations: 1,
        generic_unroll: 1,
        candidate_unrolls: vec![4],
        min_lanes: 4,
        min_batch: 1,
        decision_window: 2,
    });

    let n = 509usize; // odd: the u=4 artifact exercises the host remainder
    let mut run = |engine: &mut Engine, mem: &mut Memory| {
        mem.i32s_mut(hc).fill(0);
        engine
            .call_idx(func, mem, &[Val::P(hc), Val::P(ha), Val::P(hb), Val::I(n as i32)])
            .unwrap();
        for i in 0..n {
            assert_eq!(mem.i32s(hc)[i], a[i] + 3 * b[i] + 1, "element {i}");
        }
    };

    // Tick 1: hot, but the generic artifact compiles in the background —
    // the function must keep interpreting, unpatched, with no transition.
    run(&mut engine, &mut mem);
    assert!(ctl.observe(&mut mgr, &mut engine, func).is_none());
    assert_eq!(ctl.tier(func), Tier::Interpreter);
    assert!(!engine.is_patched(func), "promotion must not stall the interpreter");

    // Barrier (test determinism): the artifact lands in the cache, and
    // the next tick promotes via a pure cache hit.
    mgr.drain_compiles();
    run(&mut engine, &mut mem);
    let t = ctl.observe(&mut mgr, &mut engine, func).expect("promotion after landing");
    assert_eq!((t.from, t.to), (Tier::Interpreter, Tier::Generic));
    assert!(engine.is_patched(func));

    // Two offloaded ticks fill the decision window; the u=4 candidate is
    // submitted in the background and the generic tier keeps serving.
    for _ in 0..2 {
        run(&mut engine, &mut mem);
        assert!(ctl.observe(&mut mgr, &mut engine, func).is_none());
    }
    assert_eq!(ctl.tier(func), Tier::Generic, "respec must defer, not swap early");
    assert!(engine.is_patched(func), "generic tier keeps serving meanwhile");

    mgr.drain_compiles();
    // The next full window swaps the landed u=4 artifact in.
    let mut swapped = None;
    for _ in 0..2 {
        run(&mut engine, &mut mem);
        swapped = swapped.or(ctl.observe(&mut mgr, &mut engine, func));
    }
    let t = swapped.expect("respecialization after landing");
    assert_eq!((t.from, t.to), (Tier::Generic, Tier::Specialized));
    assert_eq!(ctl.unroll(func), 4);
    assert_eq!(ctl.respecializations(func), 1);

    // The tentpole invariant, manager-side: nothing ever blocked in P&R.
    assert_eq!(
        mgr.compile_stall,
        std::time::Duration::ZERO,
        "deferred compiles must never stall the caller"
    );
    // Numerics through the specialized artifact remain exact.
    run(&mut engine, &mut mem);
}

#[test]
fn a3_profile_snapshot_reset_at_patch_time() {
    let mut engine = Engine::new(fig2_module()).unwrap();
    let mut mem = Memory::new();
    let n = 400usize;
    let (ha, hb, hc) = (mem.alloc_i32(n), mem.alloc_i32(n), mem.alloc_i32(n));
    let args = [Val::P(hc), Val::P(ha), Val::P(hb), Val::I(n as i32)];
    let func = engine.func_index("fig2").unwrap();
    for _ in 0..3 {
        engine.call_idx(func, &mut mem, &args).unwrap();
    }
    let pre = engine.profile(func);
    assert!(pre.counters.cycles > 0 && pre.counters.invocations == 3);

    let mut mgr =
        OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
    mgr.try_offload(&mut engine, func, None).unwrap();

    // The row was snapshot into the runtime state and reset in place.
    let st = mgr.state(func).unwrap();
    assert_eq!(st.borrow().pre_patch.counters.invocations, 3);
    assert!(st.borrow().pre_patch.counters.cycles > 0);
    assert_eq!(engine.profile(func).counters.cycles, 0);
    assert_eq!(engine.profile(func).counters.invocations, 0);
    // The rollback baseline survives the reset.
    assert!(st.borrow().baseline_per_inv > std::time::Duration::ZERO);

    // Post-patch, the monitor sees hook invocations but zero interpreter
    // cycles: post-offload averages are unpolluted by pre-offload samples.
    for _ in 0..4 {
        engine.call_idx(func, &mut mem, &args).unwrap();
    }
    let post = engine.profile(func);
    assert_eq!(post.counters.invocations, 4);
    assert_eq!(post.counters.cycles, 0);
    let mut mon = Monitor::new(Default::default());
    assert!(
        mon.sample(&engine).is_empty(),
        "monitor must not flag a hotspot from pre-patch residue"
    );
}

//! Property-based tests (self-contained generator harness; proptest is not
//! in the offline image). Core invariants:
//!   P1  any DFG routed by the Las-Vegas P&R evaluates on the routed
//!       config's execution image exactly like direct DFG evaluation;
//!   P2  the cycle-level overlay simulator agrees with the image semantics
//!       (elastic pipeline ≡ dataflow order);
//!   P3  transport accounting: tagged wire bytes = 4x payload, time is
//!       monotone in payload;
//!   P4  extraction ≡ interpreter semantics on randomized affine kernels;
//!   P5  P&R is Las-Vegas: if it returns, the config is structurally legal;
//!   P6  P&R with a fixed seed is deterministic: identical config, stats
//!       and placement on a second run over the same DFG;
//!   P7  `dfg_key` never collides for structurally distinct random DFGs,
//!       always agrees for relabeled rebuilds of the same structure, and
//!       the specialization-signature component (`spec_key`) separates
//!       artifacts without ever touching structural identity;
//!   P8  f64-seconds transfer-time model: `transfer_secs` is monotone in
//!       payload (`time(payload+1) >= time(payload)`), strictly positive
//!       for any non-zero payload (no sub-microsecond quantization to
//!       "free"), `wire_bytes` is monotone under both protocols, and
//!       Packed costs no more wire than Tagged128 beyond one header;
//!   P9  DFG partitioning is deterministic (identical tile boundaries,
//!       spill slots and per-tile structural keys on repeated cuts),
//!       `tile_key` is positional and separates distinct specialization
//!       signatures, and the cut preserves evaluation semantics;
//!   P10 fleet reliability: the retry backoff envelope is monotone in the
//!       attempt number and capped (jittered delays stay inside it), and
//!       under random fault schedules every remote request applies at
//!       most once — replays are bit-identical and the idempotency
//!       ledger absorbs every duplicate;
//!   P11 latency-histogram soundness: for random sample streams the
//!       recorded count is conserved across buckets and merges, reported
//!       percentiles are monotone (p50 <= p95 <= p99), every percentile
//!       is a bucket floor no larger than the true sample maximum, and
//!       identical streams produce bit-identical histograms;
//!   P12 static-verifier soundness on the clean fleet: every artifact the
//!       Las-Vegas P&R routes verifies with zero error diagnostics
//!       (`analysis::verifier`, DESIGN.md §11), and verification is
//!       deterministic and pure — two runs over the same artifact return
//!       identical diagnostic streams and never mutate the artifact;
//!   P13 kernel lowering (`dfe::lower`) is deterministic and pure — two
//!       lowerings of the same fabric are byte-identical (fingerprint
//!       included) and never mutate the fabric — and scoreboard-sound:
//!       verifier pass V6 re-proves every lowered kernel's fold/alias
//!       state, step ordering (fusion never reorders a producer past its
//!       consumer) and prefill image with zero errors, and the kernel
//!       executes bit-identically to the wave schedule it came from.

use tlo::dfe::grid::Grid;
use tlo::dfe::opcodes::{Op, ALL_OPS};
use tlo::dfe::sim::CycleSim;
use tlo::dfg::graph::{Dfg, NodeKind};
use tlo::par::{place_and_route, ParParams};
use tlo::util::prng::Rng;

/// Random DAG-shaped DFG: `n_in` inputs, `n_calc` ops, 1..3 outputs.
fn random_dfg(rng: &mut Rng, n_in: usize, n_calc: usize) -> Dfg {
    let mut g = Dfg::new();
    let mut pool: Vec<usize> = (0..n_in).map(|j| g.input(j)).collect();
    for _ in 0..rng.below(3) {
        pool.push(g.constant(rng.range_i64(-50, 50) as i32));
    }
    for _ in 0..n_calc {
        let op = loop {
            let op = ALL_OPS[rng.below(ALL_OPS.len())];
            // NOP/PASS make degenerate graphs; keep real compute.
            if !matches!(op, Op::Nop | Op::Pass) {
                break op;
            }
        };
        let a = pool[rng.below(pool.len())];
        let b = pool[rng.below(pool.len())];
        let id = if op == Op::Mux {
            let s = pool[rng.below(pool.len())];
            g.mux(a, b, s)
        } else {
            g.calc(op, a, b)
        };
        pool.push(id);
    }
    let n_out = 1 + rng.below(2);
    for j in 0..n_out {
        // Bias outputs toward late nodes so the graph stays mostly live.
        let pick = pool[pool.len() - 1 - rng.below(pool.len().min(4))];
        g.output(j, pick);
    }
    g.prune_dead()
}

#[test]
fn p1_routed_config_matches_dfg_eval() {
    let mut rng = Rng::new(2024);
    let mut routed = 0;
    for case in 0..60u64 {
        let n_in = 1 + rng.below(4);
        let n_calc = 1 + rng.below(10);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        if dfg.stats().outputs == 0 || dfg.stats().calc == 0 {
            continue;
        }
        let grid = Grid::new(6, 6);
        let mut prng = Rng::new(900 + case);
        let Ok(res) = place_and_route(&dfg, grid, &ParParams::default(), &mut prng) else {
            continue; // Las-Vegas may exhaust its budget; P5 covers legality
        };
        routed += 1;
        for trial in 0..5 {
            let mut t = Rng::new(case * 31 + trial);
            let inputs: Vec<i32> = (0..n_in).map(|_| t.any_i32() % 10_000).collect();
            let want = dfg.eval(&inputs).unwrap();
            let got = res.image.eval_scalar(&inputs);
            assert_eq!(got, want, "case {case} trial {trial}\n{dfg:?}");
        }
    }
    assert!(routed >= 30, "too few routed cases ({routed}) for the property to bite");
}

#[test]
fn p2_cycle_sim_matches_image() {
    let mut rng = Rng::new(77);
    let mut checked = 0;
    for case in 0..25u64 {
        let n_in = 1 + rng.below(3);
        let n_calc = 1 + rng.below(6);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        if dfg.stats().outputs == 0 || dfg.stats().calc == 0 {
            continue;
        }
        let mut prng = Rng::new(5000 + case);
        let Ok(res) = place_and_route(&dfg, Grid::new(5, 5), &ParParams::default(), &mut prng)
        else {
            continue;
        };
        let n = 12;
        let mut t = Rng::new(case);
        let streams: Vec<Vec<i32>> =
            (0..n_in).map(|_| (0..n).map(|_| t.any_i32() % 1000).collect()).collect();
        let mut sim = CycleSim::new(&res.config).expect("legal config");
        let out = sim.run_stream(&streams, n).expect("no deadlock");
        for lane in 0..n {
            let inputs: Vec<i32> = (0..n_in).map(|j| streams[j][lane]).collect();
            let want = res.image.eval_scalar(&inputs);
            for (j, w) in want.iter().enumerate() {
                assert_eq!(out.outputs[j][lane], *w, "case {case} lane {lane} out {j}");
            }
        }
        checked += 1;
    }
    assert!(checked >= 10, "too few cycle-sim cases ({checked})");
}

#[test]
fn p3_transport_accounting() {
    use tlo::transport::{PcieParams, PcieSim, Protocol};
    let mut rng = Rng::new(3);
    let mut prev = (0u64, std::time::Duration::ZERO);
    let mut sizes: Vec<u64> = (0..200).map(|_| 4 * (1 + rng.below(1 << 18) as u64)).collect();
    sizes.sort_unstable();
    for payload in sizes {
        assert_eq!(Protocol::Tagged128.wire_bytes(payload), payload * 4);
        let mut sim = PcieSim::new(PcieParams::default());
        let t = sim.transfer(payload);
        if payload > prev.0 && t.used_dma {
            // Monotone within the DMA regime (PIO->DMA adds setup).
            assert!(t.time >= prev.1 || prev.1 == std::time::Duration::ZERO);
        }
        if t.used_dma {
            prev = (payload, t.time);
        }
        assert_eq!(sim.total_wire, sim.total_payload * 4);
    }
}

#[test]
fn p8_transfer_time_monotone_positive_and_packed_dominated_by_tagged() {
    use tlo::transport::{PcieParams, PcieSim, Protocol};
    for params in [PcieParams::default(), PcieParams::riffa_like()] {
        let mut rng = Rng::new(8);
        // Random payloads, plus the regimes where rounding once bit:
        // single-word PIO transfers and the DMA-threshold crossing.
        let mut sizes: Vec<u64> = (0..300)
            .map(|_| 1 + rng.below(1 << 20) as u64)
            .chain([1, 2, 3, 4, 5, 4095, 4096, 4097])
            .collect();
        sizes.sort_unstable();
        let mut prev: Option<(u64, f64)> = None;
        for &p in &sizes {
            let secs = params.transfer_secs(p);
            // No integer-Duration truncation: a tiny payload never models
            // as a free transfer.
            assert!(secs > 0.0, "payload {p} modeled free");
            assert!(
                secs >= params.pio_setup.as_secs_f64().min(params.dma_setup.as_secs_f64()),
                "payload {p} under the setup floor"
            );
            if let Some((q, qsecs)) = prev {
                assert!(
                    secs >= qsecs,
                    "monotonicity violated: time({p}) = {secs:.3e} < time({q}) = {qsecs:.3e}"
                );
            }
            prev = Some((p, secs));
            // Wire-byte monotonicity under both protocols.
            for proto in [Protocol::Tagged128, Protocol::Packed] {
                assert!(proto.wire_bytes(p + 1) >= proto.wire_bytes(p), "{proto:?} at {p}");
            }
            // Packed never costs more wire than Tagged128 beyond one
            // block header's worth of payload.
            if p >= 6 {
                assert!(
                    Protocol::Packed.wire_bytes(p) <= Protocol::Tagged128.wire_bytes(p),
                    "packed regression at payload {p}"
                );
            }
            // The accounted transfer agrees with the model exactly.
            let mut sim = PcieSim::new(params);
            assert_eq!(sim.transfer(p).secs, secs);
        }
    }
}

#[test]
fn p4_extraction_matches_interpreter_on_random_affine_kernels() {
    use tlo::analysis::scop::analyze_function;
    use tlo::dfg::extract::extract;
    use tlo::ir::func::{FuncBuilder, Module};
    use tlo::ir::instr::{BinOp, Ty};
    use tlo::jit::engine::Engine;
    use tlo::jit::interp::{Memory, Val};
    use tlo::offload::{OffloadManager, OffloadParams};

    let mut rng = Rng::new(10);
    for case in 0..20u64 {
        // Random elementwise kernel: C[i] = f(A[i], B[i]) with a random
        // op chain of depth 1..4.
        let depth = 1 + rng.below(4);
        let ops: Vec<BinOp> = (0..depth)
            .map(|_| {
                [BinOp::Add, BinOp::Sub, BinOp::Mul, BinOp::Min, BinOp::Max, BinOp::Xor]
                    [rng.below(6)]
            })
            .collect();
        let consts: Vec<i32> = (0..depth).map(|_| rng.range_i64(-9, 9) as i32).collect();
        let mut m = Module::new();
        let mut b = FuncBuilder::new(
            "k",
            &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        let ops2 = ops.clone();
        let consts2 = consts.clone();
        b.counted_loop(zero, n, move |b, i| {
            let av = b.load(Ty::I32, a, i);
            let bv = b.load(Ty::I32, bb, i);
            let mut acc = b.bin(ops2[0], Ty::I32, av, bv);
            for d in 1..ops2.len() {
                let cv = b.const_i32(consts2[d]);
                acc = b.bin(ops2[d], Ty::I32, acc, cv);
            }
            b.store(Ty::I32, c, i, acc);
        });
        m.add(b.ret(None));

        // Sanity: it extracts.
        {
            let f = m.get("k").unwrap();
            let an = analyze_function(f);
            assert!(!an.scops.is_empty(), "case {case}");
            extract(f, &an.scops[0], 2).expect("extractable");
        }

        let n_elems = 257usize; // odd -> remainder path with unroll 2
        let mut engine = Engine::new(m).unwrap();
        let mut mem = Memory::new();
        let av: Vec<i32> = (0..n_elems).map(|_| rng.any_i32() % 100_000).collect();
        let bv: Vec<i32> = (0..n_elems).map(|_| rng.any_i32() % 100_000).collect();
        let (hc, ha, hb) = (mem.alloc_i32(n_elems), mem.from_i32(&av), mem.from_i32(&bv));
        let args = [Val::P(hc), Val::P(ha), Val::P(hb), Val::I(n_elems as i32)];
        engine.call("k", &mut mem, &args).unwrap();
        let want = mem.i32s(hc).to_vec();

        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll: 2,
            seed: case,
            ..Default::default()
        });
        let f = engine.func_index("k").unwrap();
        mgr.try_offload(&mut engine, f, None).expect("offload");
        mem.i32s_mut(hc).fill(0);
        engine.call("k", &mut mem, &args).unwrap();
        assert_eq!(mem.i32s(hc), &want[..], "case {case} ops {ops:?}");
    }
}

#[test]
fn p6_par_with_fixed_seed_is_deterministic() {
    let mut rng = Rng::new(4242);
    let mut checked = 0;
    for case in 0..40u64 {
        let n_in = 1 + rng.below(3);
        let n_calc = 1 + rng.below(8);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        if dfg.stats().outputs == 0 || dfg.stats().calc == 0 {
            continue;
        }
        let run = |seed: u64| {
            let mut prng = Rng::new(seed);
            place_and_route(&dfg, Grid::new(6, 6), &ParParams::default(), &mut prng).ok()
        };
        match (run(1234 + case), run(1234 + case)) {
            (Some(x), Some(y)) => {
                assert_eq!(x.config, y.config, "case {case}: configs differ");
                assert_eq!(x.placement, y.placement, "case {case}: placements differ");
                // Stats identical modulo wall time.
                assert_eq!(
                    (
                        x.stats.placements,
                        x.stats.route_calls,
                        x.stats.pos_retries,
                        x.stats.backtracks,
                        x.stats.restarts
                    ),
                    (
                        y.stats.placements,
                        y.stats.route_calls,
                        y.stats.pos_retries,
                        y.stats.backtracks,
                        y.stats.restarts
                    ),
                    "case {case}: search statistics differ"
                );
                checked += 1;
            }
            (None, None) => {} // identically unroutable is also deterministic
            _ => panic!("case {case}: one run routed, the other did not"),
        }
    }
    assert!(checked >= 15, "too few deterministic pairs checked ({checked})");
}

#[test]
fn p7_dfg_key_and_spec_signature_properties() {
    use tlo::dfe::cache::{dfg_key, spec_key, SpecSignature};
    use tlo::dfg::graph::Node;

    /// Rebuild a DFG node-by-node from its own description: a fresh
    /// allocation with fresh (but order-preserving) NodeIds — the
    /// relabeling the order-sensitive structural hash must be blind to.
    fn rebuild(g: &Dfg) -> Dfg {
        let mut out = Dfg::default();
        for Node { kind, srcs } in &g.nodes {
            out.nodes.push(Node { kind: kind.clone(), srcs: srcs.clone() });
        }
        out
    }

    let mut rng = Rng::new(0xD1D);
    let mut seen: Vec<(u64, String)> = Vec::new();
    for case in 0..120u64 {
        let n_in = 1 + rng.below(4);
        let n_calc = 1 + rng.below(10);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        let k = dfg_key(&dfg);
        // Agreement under relabeling: clone and node-by-node rebuild.
        assert_eq!(k, dfg_key(&dfg.clone()), "case {case}: clone changed the key");
        assert_eq!(k, dfg_key(&rebuild(&dfg)), "case {case}: rebuild changed the key");
        // No collisions across structurally distinct graphs; equal
        // structure (random generators do repeat) must agree.
        let shape = format!("{:?}", dfg.nodes);
        for (k2, shape2) in &seen {
            if shape == *shape2 {
                assert_eq!(k, *k2, "case {case}: same structure, different key");
            } else {
                assert_ne!(k, *k2, "case {case}: distinct structures collide");
            }
        }
        seen.push((k, shape));

        // The specialization-signature component: stable per signature,
        // distinct across signatures, never equal to the bare key.
        let sigs = [
            SpecSignature::generic(1),
            SpecSignature::generic(4),
            SpecSignature::new(4, 6),
            SpecSignature::new(8, 6),
            SpecSignature::new(8, 9),
        ];
        for (i, a) in sigs.iter().enumerate() {
            assert_eq!(spec_key(k, *a), spec_key(k, *a));
            assert_ne!(spec_key(k, *a), k, "case {case}: signature collapsed");
            for b in &sigs[i + 1..] {
                assert_ne!(
                    spec_key(k, *a),
                    spec_key(k, *b),
                    "case {case}: signatures {a:?}/{b:?} collide"
                );
            }
        }
    }
}

#[test]
fn p5_routed_configs_are_structurally_legal() {
    let mut rng = Rng::new(555);
    for case in 0..40u64 {
        let n_in = 1 + rng.below(3);
        let n_calc = 1 + rng.below(8);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        if dfg.stats().outputs == 0 || dfg.stats().calc == 0 {
            continue;
        }
        let mut prng = Rng::new(7000 + case);
        if let Ok(res) = place_and_route(&dfg, Grid::new(6, 6), &ParParams::default(), &mut prng)
        {
            // validate() re-traces every net, checks I/O faces are border
            // and unique, every FU drives something, and the image builds.
            res.config.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
            // Used cells never exceed capacity; every placed node on a
            // distinct cell.
            let mut seen = std::collections::HashSet::new();
            for (_, cell) in &res.placement {
                assert!(seen.insert(*cell), "case {case}: cell reused");
            }
        }
    }
}

#[test]
fn p9_partitioning_is_deterministic_and_plan_keys_separate() {
    // The plan cache depends on this: the same DFG under the same budget
    // must always cut identically (tile boundaries, spill slots, local
    // index maps), per-tile keys must be deterministic and positional,
    // and distinct specialization signatures must never share tile keys.
    use tlo::dfe::cache::{dfg_key, spec_key, SpecSignature};
    use tlo::dfe::tile_key;
    use tlo::dfg::partition::{partition, TileBudget};

    let mut rng = Rng::new(0x917);
    let mut exercised = 0usize;
    for case in 0..80u64 {
        let n_in = 1 + rng.below(4);
        let n_calc = 2 + rng.below(12);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        let st = dfg.stats();
        if st.outputs == 0 || st.calc < 2 {
            continue;
        }
        let budget = TileBudget { cells: 1 + rng.below(3 * st.calc), io: 24 };
        match (partition(&dfg, budget), partition(&dfg, budget)) {
            (Err(ea), Err(eb)) => assert_eq!(ea, eb, "case {case}: errors must agree"),
            (Ok(a), Ok(b)) => {
                exercised += 1;
                assert_eq!(a.n_tiles(), b.n_tiles(), "case {case}: cut count drifted");
                assert_eq!(a.n_spills, b.n_spills, "case {case}: spill count drifted");
                let plan_key = spec_key(dfg_key(&dfg), SpecSignature::new(4, 1));
                let other_key = spec_key(dfg_key(&dfg), SpecSignature::new(8, 1));
                for (idx, (ta, tb)) in a.tiles.iter().zip(&b.tiles).enumerate() {
                    assert_eq!(ta.sources, tb.sources, "case {case} tile {idx}: sources");
                    assert_eq!(ta.sinks, tb.sinks, "case {case} tile {idx}: sinks");
                    let (ka, kb) = (dfg_key(&ta.dfg), dfg_key(&tb.dfg));
                    assert_eq!(ka, kb, "case {case} tile {idx}: cut DFGs must hash alike");
                    assert_eq!(tile_key(plan_key, idx, ka), tile_key(plan_key, idx, kb));
                    assert_ne!(
                        tile_key(plan_key, idx, ka),
                        tile_key(other_key, idx, ka),
                        "case {case} tile {idx}: tiles of distinct specializations collide"
                    );
                }
                // Determinism is not vacuous: the cut preserves semantics.
                let mut t = Rng::new(case * 17 + 3);
                let inputs: Vec<i32> = (0..n_in).map(|_| t.any_i32() % 10_000).collect();
                let via_a = a.eval(&inputs).unwrap();
                assert_eq!(via_a, b.eval(&inputs).unwrap(), "case {case}: evals diverge");
                assert_eq!(via_a, dfg.eval(&inputs).unwrap(), "case {case}: cut broke values");
            }
            _ => panic!("case {case}: partition flip-flopped between Ok and Err"),
        }
    }
    assert!(exercised >= 30, "only {exercised} partitions exercised — property too weak");
}

#[test]
fn p10_fleet_backoff_and_retry_idempotency_under_random_faults() {
    use tlo::offload::fleet::{backoff_delay, backoff_envelope, FleetParams, FleetServer};
    use tlo::offload::server::{polybench_mix, ServeParams};
    use tlo::transport::{FaultProfile, NetParams};

    // Backoff: the envelope is monotone non-decreasing in the attempt
    // number, never exceeds the cap, and the jittered delay always lands
    // inside (0, envelope] (decorrelated but bounded retransmit pacing).
    let mut rng = Rng::new(0xB0FF);
    for _ in 0..50 {
        let base = 1e-4 * (1.0 + rng.f64() * 9.0);
        let cap = base * (1.0 + rng.f64() * 31.0);
        let mut prev = 0.0;
        for attempt in 0..12 {
            let env = backoff_envelope(base, cap, attempt);
            assert!(env >= prev, "envelope must be monotone in attempt");
            assert!(env <= cap, "envelope must respect the cap");
            let d = backoff_delay(base, cap, attempt, &mut rng);
            assert!(d > 0.0 && d <= env, "delay {d} outside (0, {env}]");
            prev = env;
        }
    }

    // Retry idempotency under random fault schedules: however lossy the
    // links, every dispatched remote request applies at most once (the
    // rest degrade to the local fabric), the ledger absorbs every
    // duplicate, and a replay from the same seed is bit-identical.
    let mut exercised_dups = 0u64;
    let mut exercised_remote = 0u64;
    for case in 0..4u64 {
        let fault = FaultProfile {
            drop: rng.f64() * 0.5,
            dup: rng.f64() * 0.5,
            reorder: rng.f64() * 0.5,
            jitter: rng.f64() * 0.5,
            crash: rng.f64() * 0.2,
        };
        let run = |seed: u64| {
            let serve = ServeParams { rollback_window: u64::MAX, ..Default::default() };
            let fleet = FleetParams {
                nodes: 2,
                net: NetParams { fault, ..NetParams::lan_like() },
                fault_seed: seed,
                ..Default::default()
            };
            let mut s = FleetServer::new(serve, fleet, polybench_mix(3)).expect("fleet");
            let rep = s.run(4);
            let outs: Vec<Vec<Vec<i32>>> =
                (0..s.n_tenants()).map(|i| s.tenant_outputs(i)).collect();
            (rep.counters, outs)
        };
        let (ca, outs_a) = run(1000 + case);
        let (cb, outs_b) = run(1000 + case);
        assert_eq!(ca, cb, "case {case}: replay diverged");
        assert_eq!(outs_a, outs_b, "case {case}: numerics diverged across replays");
        assert!(ca.applied_results <= ca.remote_requests, "case {case}: over-application");
        assert_eq!(
            ca.applied_results + ca.fallback_local,
            ca.remote_requests,
            "case {case}: every remote request must apply once or degrade once"
        );
        exercised_dups += ca.dup_suppressed;
        exercised_remote += ca.remote_requests;
    }
    assert!(exercised_remote > 0, "random cases never dispatched remote work");
    assert!(exercised_dups > 0, "random profiles never exercised duplicate suppression");
}

#[test]
fn p11_latency_histogram_percentiles_are_monotone_conserved_and_deterministic() {
    use std::time::Duration;
    use tlo::offload::latency::{LatencyHist, LAT_BUCKETS};

    let mut rng = Rng::new(0x1A7);
    let mut nonempty = 0usize;
    for case in 0..150u64 {
        let n = rng.below(200);
        // Span every magnitude the serve layer produces: sub-microsecond
        // fabric times up to multi-second compile stalls.
        let samples: Vec<u64> = (0..n)
            .map(|_| {
                let mag = rng.below(40) as u32;
                let base = 1u64 << mag.min(39);
                base + rng.below(base.min(1 << 20) as usize) as u64
            })
            .collect();
        let mut h = LatencyHist::new();
        for &s in &samples {
            h.record(Duration::from_nanos(s));
        }

        // Conservation: every sample lands in exactly one bucket.
        assert_eq!(h.total(), n as u64, "case {case}");
        let bucket_sum: u64 = h.counts().iter().sum();
        assert_eq!(bucket_sum, n as u64, "case {case}: buckets leak samples");

        // Determinism: the same stream is bit-identical.
        let mut h2 = LatencyHist::new();
        for &s in &samples {
            h2.record(Duration::from_nanos(s));
        }
        assert_eq!(h, h2, "case {case}: identical streams diverged");

        // Merge conservation: any split of the stream folds back exactly.
        let cut = rng.below(samples.len().max(1));
        let (left, right) = samples.split_at(cut);
        let mut ha = LatencyHist::new();
        let mut hb = LatencyHist::new();
        for &s in left {
            ha.record(Duration::from_nanos(s));
        }
        for &s in right {
            hb.record(Duration::from_nanos(s));
        }
        ha.merge(&hb);
        assert_eq!(ha, h, "case {case}: merge is not record-equivalent");

        if n == 0 {
            assert_eq!(h.p99(), Duration::ZERO, "case {case}: empty hist must read zero");
            continue;
        }
        nonempty += 1;

        // Percentile monotonicity, both across the named trio and along a
        // sweep of the full range.
        assert!(h.p50() <= h.p95() && h.p95() <= h.p99(), "case {case}");
        let mut prev = Duration::ZERO;
        for i in 0..=20 {
            let q = h.percentile(i as f64 / 20.0);
            assert!(q >= prev, "case {case}: percentile sweep not monotone at {i}");
            prev = q;
        }

        // Every reported percentile is a bucket floor: never above the
        // true sample maximum, and p99 at least the floor of the median
        // sample's bucket (the floor halves a value at worst).
        let max = *samples.iter().max().unwrap();
        assert!(
            h.p99() <= Duration::from_nanos(max),
            "case {case}: p99 {:?} above the true max {max}ns",
            h.p99()
        );
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        let median = sorted[(n - 1) / 2];
        assert!(
            h.p99().as_nanos() as u64 >= median / 2,
            "case {case}: p99 {:?} below half the median {median}ns",
            h.p99()
        );
    }
    assert!(nonempty >= 100, "only {nonempty} non-empty cases — property too weak");
    // The bucket axis is part of the persisted format: changing it
    // silently would corrupt merged cross-node histograms.
    assert_eq!(LAT_BUCKETS, 33);
}

#[test]
fn p12_routed_artifacts_verify_clean_and_verification_is_pure() {
    use tlo::analysis::diag::{render_table, Severity};
    use tlo::analysis::verifier::verify_artifact;
    use tlo::dfe::cache::CachedConfig;

    let mut rng = Rng::new(0x12_12);
    let grid = Grid::new(6, 6);
    let mut routed = 0;
    for case in 0..200u64 {
        let n_in = 1 + rng.below(4);
        let n_calc = 2 + rng.below(10);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        if dfg.stats().outputs == 0 || dfg.stats().calc == 0 {
            continue;
        }
        let mut prng = Rng::new(0x12_00 + case);
        let Ok(res) = place_and_route(&dfg, grid, &ParParams::default(), &mut prng) else {
            continue; // Las-Vegas: this seed lost; the property is about routed artifacts
        };
        routed += 1;
        let image = res.config.to_image().expect("routed configs lower to images");
        let cached = CachedConfig::new(res.config, image, format!("p12_{case}"));

        // Soundness: nothing the real pipeline routes may be flagged as
        // an error (warnings — advisory convention drift — are allowed).
        let first = verify_artifact(&cached);
        assert!(
            !first.iter().any(|d| d.severity == Severity::Error),
            "case {case}: routed artifact flagged\n{}",
            render_table(&first)
        );

        // Determinism + purity: a second run over the untouched artifact
        // is diagnostic-identical, and verification never mutated the
        // artifact (the image still lowers from the same config).
        let again = verify_artifact(&cached);
        assert_eq!(first, again, "case {case}: verify is not deterministic");
        assert_eq!(
            cached.config.to_image().expect("still lowers"),
            cached.image,
            "case {case}: verification mutated the artifact"
        );

        // The diagnostic stream is canonically ordered (sorted).
        let mut sorted = first.clone();
        tlo::analysis::diag::sort_diags(&mut sorted);
        assert_eq!(first, sorted, "case {case}: diagnostics not in canonical order");
    }
    assert!(routed >= 60, "only {routed}/200 cases routed — property too weak");
}

#[test]
fn p13_lowering_is_deterministic_pure_and_scoreboard_sound() {
    use tlo::analysis::diag::{render_table, Severity};
    use tlo::analysis::verifier::verify_lowered;
    use tlo::dfe::exec::CompiledFabric;
    use tlo::dfe::{LoweredKernel, Scratch};

    let mut rng = Rng::new(0x13_13);
    let grid = Grid::new(6, 6);
    let mut routed = 0;
    for case in 0..200u64 {
        let n_in = 1 + rng.below(4);
        let n_calc = 2 + rng.below(10);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        if dfg.stats().outputs == 0 || dfg.stats().calc == 0 {
            continue;
        }
        let mut prng = Rng::new(0x13_00 + case);
        let Ok(res) = place_and_route(&dfg, grid, &ParParams::default(), &mut prng) else {
            continue; // Las-Vegas: this seed lost
        };
        routed += 1;
        let fab = CompiledFabric::compile(&res.config).expect("routed config lowers");

        // Purity probe taken before lowering.
        let lanes = 96;
        let mut t = Rng::new(case * 7 + 1);
        let x: Vec<i32> = (0..fab.n_inputs * lanes).map(|_| t.any_i32()).collect();
        let before = fab.run_batch(&x, lanes);

        // Determinism: two lowerings of the same fabric are byte-identical,
        // fingerprint included (the scratch-arena priming key depends on it).
        let k1 = LoweredKernel::lower(&fab);
        let k2 = LoweredKernel::lower(&fab);
        assert_eq!(k1, k2, "case {case}: lowering is not deterministic");
        assert_eq!(k1.fingerprint, k2.fingerprint, "case {case}: fingerprint drift");

        // Purity: lowering never disturbs the fabric it lowered from.
        assert_eq!(before, fab.run_batch(&x, lanes), "case {case}: lowering mutated the fabric");

        // Scoreboard soundness: V6 independently re-derives the
        // fold/alias abstract state and re-proves every surviving step
        // defined-before-use with operands strictly below the destination
        // — fusion may never reorder a producer past its consumer. Zero
        // errors on anything the lowering emits.
        let diags = verify_lowered(&fab, &k1);
        assert!(
            !diags.iter().any(|d| d.severity == Severity::Error),
            "case {case}: lowered kernel flagged\n{}",
            render_table(&diags)
        );

        // Numeric backstop for the structural proof: the kernel executes
        // bit-identically through a fresh arena.
        let mut scratch = Scratch::new();
        assert_eq!(k1.run_batch(&x, lanes, &mut scratch), before, "case {case}: diverges");
    }
    assert!(routed >= 60, "only {routed}/200 cases routed — property too weak");
}

//! Differential conformance suite: the lockdown for the whole offload
//! surface (analysis → extraction → P&R → stub → backend numerics).
//!
//! For every PolyBench kernel and the §IV-C video convolution, across at
//! least three dataset sizes each:
//!
//!   interpreter ≡ offloaded (CycleSim backend)
//!               ≡ offloaded (Auto backend: the **lowered batch
//!                 kernels**, `dfe::lower` — the production default)
//!               ≡ offloaded (Auto with lowering disabled: the compiled
//!                 wave / Fabric interpreter, the `--no-lower` fallback)
//!               ≡ the `*_reference` host oracle,     bit for bit.
//!
//! Kernels the paper rejects (multi-SCoP, divisions, fp data, no SCoP)
//! must *refuse* the offload and still match the oracle in software —
//! the refusal path is part of the conformance surface. Dedicated tests
//! cover sizes below the offload threshold (must stay on the interpreter)
//! and sizes that straddle the adaptive controller's tier boundaries.
//!
//! On failure the mismatch report is appended to
//! `../conformance_diff.txt` (repo root) so CI can upload it as an
//! artifact.

use std::fmt::Write as _;

use tlo::ir::func::Module;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::offload::adapt::{AdaptController, AdaptParams, Tier};
use tlo::offload::{OffloadManager, OffloadParams, RejectReason, SimBackendChoice};
use tlo::workloads::polybench as pb;
use tlo::workloads::video;

/// Append the mismatch report to the repo-root diff artifact, then panic.
fn fail_with_diff(section: &str, diff: String) -> ! {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../conformance_diff.txt");
    use std::io::Write as _;
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open(path)
    {
        let _ = writeln!(f, "== {section} ==\n{diff}");
    }
    panic!("conformance failure in {section} (see conformance_diff.txt):\n{diff}");
}

/// One kernel under differential test.
struct Case {
    name: &'static str,
    module: fn() -> Module,
    func: &'static str,
    unroll: usize,
    /// Offloadable through the single-SCoP stub contract?
    offloadable: bool,
    /// Allocate + fill buffers for size `n`; returns (args, out handles).
    setup: fn(&mut Memory, usize) -> (Vec<Val>, Vec<u32>),
    /// The host oracle, applied to a clone of the same initial memory.
    reference: fn(&mut Memory, &[Val], usize),
    sizes: &'static [usize],
}

/// Deterministic fill data (kernel-salted, sign-mixed, small enough that
/// i32 products stay meaningful).
fn data(len: usize, salt: i32) -> Vec<i32> {
    (0..len).map(|i| ((i as i32).wrapping_mul(7).wrapping_add(salt)) % 13 - 6).collect()
}

fn outs(mem: &Memory, handles: &[u32]) -> Vec<Vec<i32>> {
    handles.iter().map(|&h| mem.i32s(h).to_vec()).collect()
}

/// Run one mode: `None` = pure interpreter; `Some(backend)` = offload
/// attempt through the real manager + stub with that sim backend pinned.
/// Returns (outputs, offloaded?).
fn run_mode(
    case: &Case,
    n: usize,
    backend: Option<SimBackendChoice>,
) -> (Vec<Vec<i32>>, bool) {
    run_mode_with(case, n, backend, true)
}

/// `run_mode` with the kernel-lowering switch exposed: `lower = true` is
/// the production default (Auto executes through the lowered batch
/// kernels), `lower = false` pins the compiled-wave interpreter — the
/// same fallback `tlo serve --no-lower` selects.
fn run_mode_with(
    case: &Case,
    n: usize,
    backend: Option<SimBackendChoice>,
    lower: bool,
) -> (Vec<Vec<i32>>, bool) {
    let mut engine = Engine::new((case.module)()).expect("module");
    let mut mem = Memory::new();
    let (args, handles) = (case.setup)(&mut mem, n);
    let func = engine.func_index(case.func).expect("func");
    let mut offloaded = false;
    if let Some(sim_backend) = backend {
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll: case.unroll,
            sim_backend,
            lower,
            ..Default::default()
        });
        match mgr.try_offload(&mut engine, func, None) {
            Ok(_) => offloaded = true,
            Err(e) => {
                assert!(
                    !case.offloadable,
                    "{}: offload unexpectedly refused: {e}",
                    case.name
                );
            }
        }
    }
    engine.call_idx(func, &mut mem, &args).expect("run");
    (outs(&mem, &handles), offloaded)
}

/// The differential check for one kernel at all its sizes.
fn conformance(case: &Case) {
    for &n in case.sizes {
        // Oracle on a clone of the exact same initial memory.
        let want = {
            let mut mem = Memory::new();
            let (args, handles) = (case.setup)(&mut mem, n);
            (case.reference)(&mut mem, &args, n);
            outs(&mem, &handles)
        };
        let (interp, _) = run_mode(case, n, None);
        let (cycle, off_c) = run_mode(case, n, Some(SimBackendChoice::CycleSim));
        // Auto with lowering on (the default hot path: lowered batch
        // kernels) and off (the compiled-wave `--no-lower` fallback).
        let (lowered, off_l) = run_mode(case, n, Some(SimBackendChoice::Auto));
        let (wave, off_w) = run_mode_with(case, n, Some(SimBackendChoice::Auto), false);
        if case.offloadable {
            assert!(
                off_c && off_l && off_w,
                "{} n={n}: expected the offload to engage",
                case.name
            );
        } else {
            assert!(!off_c && !off_l && !off_w, "{} n={n}: must stay in software", case.name);
        }
        let runs = [
            ("interpreter", &interp),
            ("cyclesim", &cycle),
            ("lowered", &lowered),
            ("wave", &wave),
        ];
        for (mode, got) in runs {
            if *got != want {
                let mut diff = String::new();
                let _ = writeln!(diff, "kernel {} n={n} mode {mode}", case.name);
                for (oi, (g, w)) in got.iter().zip(&want).enumerate() {
                    for (ei, (gv, wv)) in g.iter().zip(w).enumerate() {
                        if gv != wv {
                            let _ = writeln!(
                                diff,
                                "  out[{oi}][{ei}]: got {gv}, want {wv}"
                            );
                        }
                    }
                    if g.len() != w.len() {
                        let _ = writeln!(
                            diff,
                            "  out[{oi}]: length {} vs {}",
                            g.len(),
                            w.len()
                        );
                    }
                }
                fail_with_diff(case.name, diff);
            }
        }
    }
}

// ---------------- setups + oracle adapters ----------------

fn mat_args3(mem: &mut Memory, n: usize, salt: i32, alpha: i32) -> (Vec<Val>, Vec<u32>) {
    // C, A, B, alpha, n — the gemm/syr2k/symm shape.
    let ha = mem.from_i32(&data(n * n, salt));
    let hb = mem.from_i32(&data(n * n, salt + 3));
    let hc = mem.from_i32(&data(n * n, salt + 5));
    (
        vec![Val::P(hc), Val::P(ha), Val::P(hb), Val::I(alpha), Val::I(n as i32)],
        vec![hc],
    )
}

fn gemm_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    mat_args3(mem, n, 1, 2)
}
fn gemm_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[1].as_ptr()).to_vec();
    let b = mem.i32s(args[2].as_ptr()).to_vec();
    let alpha = args[3].as_i32();
    pb::gemm_reference(mem.i32s_mut(args[0].as_ptr()), &a, &b, alpha, n);
}

fn two_mm_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let (mut args, mut outs) = mat_args3(mem, n, 11, 2);
    let ht1 = mem.from_i32(&data(n * n, 17));
    args.push(Val::P(ht1));
    outs.push(ht1);
    (args, outs)
}
fn two_mm_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[1].as_ptr()).to_vec();
    let b = mem.i32s(args[2].as_ptr()).to_vec();
    let alpha = args[3].as_i32();
    let mut c = mem.i32s(args[0].as_ptr()).to_vec();
    let mut t1 = mem.i32s(args[5].as_ptr()).to_vec();
    pb::two_mm_reference(&mut c, &a, &b, &mut t1, alpha, n);
    mem.i32s_mut(args[0].as_ptr()).copy_from_slice(&c);
    mem.i32s_mut(args[5].as_ptr()).copy_from_slice(&t1);
}

fn three_mm_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let (mut args, mut outs) = mat_args3(mem, n, 23, 2);
    let ht1 = mem.from_i32(&data(n * n, 29));
    let ht2 = mem.from_i32(&data(n * n, 31));
    args.push(Val::P(ht1));
    args.push(Val::P(ht2));
    outs.push(ht1);
    outs.push(ht2);
    (args, outs)
}
fn three_mm_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[1].as_ptr()).to_vec();
    let b = mem.i32s(args[2].as_ptr()).to_vec();
    let alpha = args[3].as_i32();
    let mut c = mem.i32s(args[0].as_ptr()).to_vec();
    let mut t1 = mem.i32s(args[5].as_ptr()).to_vec();
    let mut t2 = mem.i32s(args[6].as_ptr()).to_vec();
    pb::three_mm_reference(&mut c, &a, &b, &mut t1, &mut t2, alpha, n);
    mem.i32s_mut(args[0].as_ptr()).copy_from_slice(&c);
    mem.i32s_mut(args[5].as_ptr()).copy_from_slice(&t1);
    mem.i32s_mut(args[6].as_ptr()).copy_from_slice(&t2);
}

fn atax_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n, 2));
    let hx = mem.from_i32(&data(n, 4));
    let hy = mem.from_i32(&data(n, 6));
    let htmp = mem.from_i32(&data(n, 8));
    (
        vec![Val::P(ha), Val::P(hx), Val::P(hy), Val::P(htmp), Val::I(n as i32)],
        vec![hy, htmp],
    )
}
fn atax_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[0].as_ptr()).to_vec();
    let x = mem.i32s(args[1].as_ptr()).to_vec();
    let mut y = mem.i32s(args[2].as_ptr()).to_vec();
    let mut tmp = mem.i32s(args[3].as_ptr()).to_vec();
    pb::atax_reference(&a, &x, &mut y, &mut tmp, n);
    mem.i32s_mut(args[2].as_ptr()).copy_from_slice(&y);
    mem.i32s_mut(args[3].as_ptr()).copy_from_slice(&tmp);
}

fn bicg_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n, 3));
    let hs = mem.from_i32(&data(n, 5));
    let hq = mem.from_i32(&data(n, 7));
    let hp = mem.from_i32(&data(n, 9));
    let hr = mem.from_i32(&data(n, 11));
    (
        vec![Val::P(ha), Val::P(hs), Val::P(hq), Val::P(hp), Val::P(hr), Val::I(n as i32)],
        vec![hs, hq],
    )
}
fn bicg_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[0].as_ptr()).to_vec();
    let mut s = mem.i32s(args[1].as_ptr()).to_vec();
    let mut q = mem.i32s(args[2].as_ptr()).to_vec();
    let p = mem.i32s(args[3].as_ptr()).to_vec();
    let r = mem.i32s(args[4].as_ptr()).to_vec();
    pb::bicg_reference(&a, &mut s, &mut q, &p, &r, n);
    mem.i32s_mut(args[1].as_ptr()).copy_from_slice(&s);
    mem.i32s_mut(args[2].as_ptr()).copy_from_slice(&q);
}

fn mvt_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n, 13));
    let hx1 = mem.from_i32(&data(n, 15));
    let hx2 = mem.from_i32(&data(n, 17));
    let hy1 = mem.from_i32(&data(n, 19));
    let hy2 = mem.from_i32(&data(n, 21));
    (
        vec![Val::P(ha), Val::P(hx1), Val::P(hx2), Val::P(hy1), Val::P(hy2), Val::I(n as i32)],
        vec![hx1, hx2],
    )
}
fn mvt_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[0].as_ptr()).to_vec();
    let mut x1 = mem.i32s(args[1].as_ptr()).to_vec();
    let mut x2 = mem.i32s(args[2].as_ptr()).to_vec();
    let y1 = mem.i32s(args[3].as_ptr()).to_vec();
    let y2 = mem.i32s(args[4].as_ptr()).to_vec();
    pb::mvt_reference(&a, &mut x1, &mut x2, &y1, &y2, n);
    mem.i32s_mut(args[1].as_ptr()).copy_from_slice(&x1);
    mem.i32s_mut(args[2].as_ptr()).copy_from_slice(&x2);
}

fn gemver_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n, 1));
    let hu1 = mem.from_i32(&data(n, 2));
    let hv1 = mem.from_i32(&data(n, 3));
    let hu2 = mem.from_i32(&data(n, 4));
    let hv2 = mem.from_i32(&data(n, 5));
    let hx = mem.from_i32(&data(n, 6));
    let hy = mem.from_i32(&data(n, 7));
    (
        vec![
            Val::P(ha),
            Val::P(hu1),
            Val::P(hv1),
            Val::P(hu2),
            Val::P(hv2),
            Val::P(hx),
            Val::P(hy),
            Val::I(n as i32),
        ],
        vec![ha, hx],
    )
}
fn gemver_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let mut a = mem.i32s(args[0].as_ptr()).to_vec();
    let u1 = mem.i32s(args[1].as_ptr()).to_vec();
    let v1 = mem.i32s(args[2].as_ptr()).to_vec();
    let u2 = mem.i32s(args[3].as_ptr()).to_vec();
    let v2 = mem.i32s(args[4].as_ptr()).to_vec();
    let mut x = mem.i32s(args[5].as_ptr()).to_vec();
    let y = mem.i32s(args[6].as_ptr()).to_vec();
    pb::gemver_reference(&mut a, &u1, &v1, &u2, &v2, &mut x, &y, n);
    mem.i32s_mut(args[0].as_ptr()).copy_from_slice(&a);
    mem.i32s_mut(args[5].as_ptr()).copy_from_slice(&x);
}

fn gesummv_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n, 8));
    let hb = mem.from_i32(&data(n * n, 10));
    let hx = mem.from_i32(&data(n, 12));
    let htmp = mem.from_i32(&data(n, 14));
    let hy = mem.from_i32(&data(n, 16));
    (
        vec![
            Val::P(ha),
            Val::P(hb),
            Val::P(hx),
            Val::P(htmp),
            Val::P(hy),
            Val::I(3),
            Val::I(2),
            Val::I(n as i32),
        ],
        vec![htmp, hy],
    )
}
fn gesummv_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[0].as_ptr()).to_vec();
    let b = mem.i32s(args[1].as_ptr()).to_vec();
    let x = mem.i32s(args[2].as_ptr()).to_vec();
    let mut tmp = mem.i32s(args[3].as_ptr()).to_vec();
    let mut y = mem.i32s(args[4].as_ptr()).to_vec();
    pb::gesummv_reference(&a, &b, &x, &mut tmp, &mut y, 3, 2, n);
    mem.i32s_mut(args[3].as_ptr()).copy_from_slice(&tmp);
    mem.i32s_mut(args[4].as_ptr()).copy_from_slice(&y);
}

fn syrk_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n, 18));
    let hc = mem.from_i32(&data(n * n, 20));
    (vec![Val::P(hc), Val::P(ha), Val::I(3), Val::I(n as i32)], vec![hc])
}
fn syrk_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[1].as_ptr()).to_vec();
    pb::syrk_reference(mem.i32s_mut(args[0].as_ptr()), &a, 3, n);
}

fn syr2k_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    mat_args3(mem, n, 22, 3)
}
fn syr2k_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[1].as_ptr()).to_vec();
    let b = mem.i32s(args[2].as_ptr()).to_vec();
    pb::syr2k_reference(mem.i32s_mut(args[0].as_ptr()), &a, &b, 3, n);
}

fn symm_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    mat_args3(mem, n, 24, 2)
}
fn symm_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[1].as_ptr()).to_vec();
    let b = mem.i32s(args[2].as_ptr()).to_vec();
    pb::symm_reference(mem.i32s_mut(args[0].as_ptr()), &a, &b, 2, n);
}

fn trmm_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n, 26));
    let hb = mem.from_i32(&data(n * n, 28));
    let hbo = mem.from_i32(&data(n * n, 30));
    (vec![Val::P(hbo), Val::P(ha), Val::P(hb), Val::I(n as i32)], vec![hbo])
}
fn trmm_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let a = mem.i32s(args[1].as_ptr()).to_vec();
    let b = mem.i32s(args[2].as_ptr()).to_vec();
    pb::trmm_reference(mem.i32s_mut(args[0].as_ptr()), &a, &b, n);
}

fn heat3d_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    let ha = mem.from_i32(&data(n * n * n, 32));
    let hb = mem.from_i32(&data(n * n * n, 34));
    (
        vec![Val::P(ha), Val::P(hb), Val::I(n as i32), Val::I((n * n) as i32)],
        vec![ha, hb],
    )
}
fn heat3d_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let mut a = mem.i32s(args[0].as_ptr()).to_vec();
    let mut b = mem.i32s(args[1].as_ptr()).to_vec();
    pb::heat3d_reference(&mut a, &mut b, n);
    mem.i32s_mut(args[0].as_ptr()).copy_from_slice(&a);
    mem.i32s_mut(args[1].as_ptr()).copy_from_slice(&b);
}

fn conv_setup(mem: &mut Memory, n: usize) -> (Vec<Val>, Vec<u32>) {
    // n indexes the frame geometry (w = 2n, h = n keeps it non-square).
    let (w, h) = (2 * n, n);
    let hout = mem.from_i32(&data(w * h, 36));
    let hin = mem.from_i32(&data(w * h, 38));
    let hcoef = mem.from_i32(&video::COEF);
    (
        vec![Val::P(hout), Val::P(hin), Val::P(hcoef), Val::I(w as i32), Val::I(h as i32)],
        vec![hout],
    )
}
fn conv_ref(mem: &mut Memory, args: &[Val], n: usize) {
    let (w, h) = (2 * n, n);
    let inp = mem.i32s(args[1].as_ptr()).to_vec();
    let coef = mem.i32s(args[2].as_ptr()).to_vec();
    let want = video::conv_reference(&inp, &coef, w, h);
    // conv only writes the interior; the border keeps its initial fill.
    let out = mem.i32s_mut(args[0].as_ptr());
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            out[y * w + x] = want[y * w + x];
        }
    }
}

fn module_of(f: fn() -> tlo::ir::func::Function) -> Module {
    let mut m = Module::new();
    m.add(f());
    m
}

fn cases() -> Vec<Case> {
    // Sizes are picked so the smallest exercises degenerate iteration
    // spaces, the middle is odd (remainder path under unroll), and the
    // largest straddles the controller's specialization boundary.
    const MAT: &[usize] = &[2, 5, 9];
    vec![
        Case {
            name: "gemm",
            module: || module_of(pb::gemm),
            func: "gemm",
            unroll: 2,
            offloadable: true,
            setup: gemm_setup,
            reference: gemm_ref,
            sizes: MAT,
        },
        Case {
            name: "2mm",
            module: || module_of(pb::two_mm),
            func: "2mm",
            unroll: 2,
            offloadable: false, // two chained nests: multi-SCoP
            setup: two_mm_setup,
            reference: two_mm_ref,
            sizes: MAT,
        },
        Case {
            name: "3mm",
            module: || module_of(pb::three_mm),
            func: "3mm",
            unroll: 2,
            offloadable: false,
            setup: three_mm_setup,
            reference: three_mm_ref,
            sizes: MAT,
        },
        Case {
            name: "atax",
            module: || module_of(pb::atax),
            func: "atax",
            unroll: 2,
            offloadable: false,
            setup: atax_setup,
            reference: atax_ref,
            sizes: MAT,
        },
        Case {
            name: "bicg",
            module: || module_of(pb::bicg),
            func: "bicg",
            unroll: 2,
            offloadable: false,
            setup: bicg_setup,
            reference: bicg_ref,
            sizes: MAT,
        },
        Case {
            name: "mvt",
            module: || module_of(pb::mvt),
            func: "mvt",
            unroll: 2,
            offloadable: false,
            setup: mvt_setup,
            reference: mvt_ref,
            sizes: MAT,
        },
        Case {
            name: "gemver",
            module: || module_of(pb::gemver),
            func: "gemver",
            unroll: 2,
            offloadable: false,
            setup: gemver_setup,
            reference: gemver_ref,
            sizes: MAT,
        },
        Case {
            name: "gesummv",
            module: || module_of(pb::gesummv),
            func: "gesummv",
            unroll: 2,
            offloadable: true,
            setup: gesummv_setup,
            reference: gesummv_ref,
            sizes: MAT,
        },
        Case {
            name: "syrk",
            module: || module_of(pb::syrk),
            func: "syrk",
            unroll: 2,
            offloadable: true,
            setup: syrk_setup,
            reference: syrk_ref,
            sizes: MAT,
        },
        Case {
            name: "syr2k",
            module: || module_of(pb::syr2k),
            func: "syr2k",
            unroll: 2,
            offloadable: true,
            setup: syr2k_setup,
            reference: syr2k_ref,
            sizes: MAT,
        },
        Case {
            name: "symm",
            module: || module_of(pb::symm),
            func: "symm",
            unroll: 2,
            offloadable: true,
            setup: symm_setup,
            reference: symm_ref,
            sizes: MAT,
        },
        Case {
            name: "trmm",
            module: || module_of(pb::trmm),
            func: "trmm",
            unroll: 2,
            offloadable: true,
            setup: trmm_setup,
            reference: trmm_ref,
            sizes: MAT,
        },
        Case {
            name: "heat-3d",
            module: || module_of(pb::heat3d),
            func: "heat-3d",
            unroll: 2,
            offloadable: false, // two ping-pong nests: multi-SCoP
            setup: heat3d_setup,
            reference: heat3d_ref,
            sizes: &[3, 4, 6],
        },
        Case {
            name: "conv",
            module: video::video_module,
            func: "conv",
            unroll: 1,
            offloadable: true,
            setup: conv_setup,
            reference: conv_ref,
            sizes: &[3, 7, 12],
        },
    ]
}

// One #[test] per kernel keeps a conformance failure attributable at a
// glance in the CI matrix.
macro_rules! conformance_test {
    ($test:ident, $kernel:expr) => {
        #[test]
        fn $test() {
            let case = cases()
                .into_iter()
                .find(|c| c.name == $kernel)
                .expect("case registered");
            conformance(&case);
        }
    };
}

conformance_test!(conformance_gemm, "gemm");
conformance_test!(conformance_2mm, "2mm");
conformance_test!(conformance_3mm, "3mm");
conformance_test!(conformance_atax, "atax");
conformance_test!(conformance_bicg, "bicg");
conformance_test!(conformance_mvt, "mvt");
conformance_test!(conformance_gemver, "gemver");
conformance_test!(conformance_gesummv, "gesummv");
conformance_test!(conformance_syrk, "syrk");
conformance_test!(conformance_syr2k, "syr2k");
conformance_test!(conformance_symm, "symm");
conformance_test!(conformance_trmm, "trmm");
conformance_test!(conformance_heat3d, "heat-3d");
conformance_test!(conformance_conv, "conv");

#[test]
fn conformance_rejected_kernels_match_reference_in_software() {
    // Division-class kernels: refusal label + software ≡ oracle.
    for (name, build) in [
        ("adi", pb::adi as fn() -> tlo::ir::func::Function),
        ("lu", pb::lu),
        ("ludcmp", pb::ludcmp),
        ("seidel", pb::seidel),
        ("trisolv", pb::trisolv),
    ] {
        for n in [2usize, 4, 7] {
            let mut engine = Engine::new(module_of(build)).unwrap();
            let mut mem = Memory::new();
            // Strictly positive data keeps every pivot nonzero.
            let a: Vec<i32> = (0..n * n).map(|i| 1 + (i as i32 % 7)).collect();
            let ha = mem.from_i32(&a);
            let args = [Val::P(ha), Val::I(n as i32)];
            let func = engine.func_index(name).unwrap();
            let mut mgr = OffloadManager::new(OffloadParams {
                min_dfg_nodes: 1,
                ..Default::default()
            });
            let err = mgr.try_offload(&mut engine, func, None).unwrap_err();
            assert!(
                matches!(err, RejectReason::Illegal(ref s) if s.contains("div")),
                "{name}: {err}"
            );
            engine.call_idx(func, &mut mem, &args).unwrap();
            let mut want = a.clone();
            pb::division_kernel_reference(&mut want, n);
            if mem.i32s(ha) != &want[..] {
                fail_with_diff(name, format!("n={n}: {:?} != {want:?}", mem.i32s(ha)));
            }
        }
    }

    // fp-data kernels: refusal label; the software path still runs.
    for (name, build) in [
        ("fdtd-2d", pb::fdtd_2d as fn() -> tlo::ir::func::Function),
        ("jacobi-1D", pb::jacobi_1d),
        ("jacobi-2D", pb::jacobi_2d),
    ] {
        let n = 6usize;
        let mut engine = Engine::new(module_of(build)).unwrap();
        let mut mem = Memory::new();
        let ha = mem.alloc_f32(n);
        let hb = mem.alloc_f32(n);
        for i in 0..n {
            mem.f32s_mut(ha)[i] = i as f32 * 0.5 - 1.0;
        }
        let args = [Val::P(ha), Val::P(hb), Val::I(n as i32)];
        let func = engine.func_index(name).unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let err = mgr.try_offload(&mut engine, func, None).unwrap_err();
        assert!(
            matches!(err, RejectReason::Illegal(ref s) if s.contains("fp")),
            "{name}: {err}"
        );
        engine.call_idx(func, &mut mem, &args).unwrap();
    }

    // No-SCoP kernels: refusal + software ≡ oracle.
    for n in [2usize, 5, 9] {
        let mut engine = Engine::new(module_of(pb::nussinov)).unwrap();
        let mut mem = Memory::new();
        let t: Vec<i32> = data(n, 40);
        let s: Vec<i32> = (0..n).map(|j| ((j * 3) % n) as i32).collect();
        let (ht, hs) = (mem.from_i32(&t), mem.from_i32(&s));
        let args = [Val::P(ht), Val::P(hs), Val::I(n as i32)];
        let func = engine.func_index("nussinov").unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        assert!(mgr.try_offload(&mut engine, func, None).is_err());
        engine.call_idx(func, &mut mem, &args).unwrap();
        let mut want = t.clone();
        pb::nussinov_reference(&mut want, &s, n);
        assert_eq!(mem.i32s(ht), &want[..], "nussinov n={n}");

        let mut engine = Engine::new(module_of(pb::floyd_warshall)).unwrap();
        let mut mem = Memory::new();
        let p0: Vec<i32> = data(n * n, 42);
        let hp = mem.from_i32(&p0);
        let func = engine.func_index("floyd-warshall").unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        assert!(mgr.try_offload(&mut engine, func, None).is_err());
        engine
            .call_idx(func, &mut mem, &[Val::P(hp), Val::I(n as i32)])
            .unwrap();
        let mut want = p0.clone();
        pb::floyd_warshall_reference(&mut want, n);
        assert_eq!(mem.i32s(hp), &want[..], "floyd-warshall n={n}");
    }

    // MUX-invalidated kernels: refusal only (side-effecting arms).
    for (name, build) in [
        ("deriche", pb::deriche as fn() -> tlo::ir::func::Function),
        ("durbin", pb::durbin),
    ] {
        let mut engine = Engine::new(module_of(build)).unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let func = engine.func_index(name).unwrap();
        assert!(mgr.try_offload(&mut engine, func, None).is_err(), "{name}");
    }
}

#[test]
fn conformance_below_threshold_stays_on_interpreter() {
    // The DFG-size floor is part of the conformance surface: a refused
    // offload must leave the function in software, bit-identical to the
    // oracle.
    for n in [2usize, 5, 9] {
        let mut engine = Engine::new(module_of(pb::gemm)).unwrap();
        let mut mem = Memory::new();
        let (args, handles) = gemm_setup(&mut mem, n);
        let func = engine.func_index("gemm").unwrap();
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1000,
            unroll: 2,
            ..Default::default()
        });
        assert!(matches!(
            mgr.try_offload(&mut engine, func, None),
            Err(RejectReason::TooSmall { .. })
        ));
        assert!(!engine.is_patched(func));
        let mut want_mem = mem.clone();
        engine.call_idx(func, &mut mem, &args).unwrap();
        gemm_ref(&mut want_mem, &args, n);
        assert_eq!(outs(&mem, &handles), outs(&want_mem, &handles), "n={n}");
    }
}

#[test]
fn conformance_across_tier_boundaries() {
    // Drive the adaptive controller over a size sweep that straddles its
    // tier boundaries: below min_batch (stays on the interpreter), mid
    // (generic tier), large (specializes). Every invocation must stay
    // bit-identical to the accumulated oracle.
    let mut engine = Engine::new(module_of(pb::gemm)).unwrap();
    let mut mem = Memory::new();
    let n_max = 8usize;
    let (args, handles) = gemm_setup(&mut mem, n_max);
    let func = engine.func_index("gemm").unwrap();
    let mut want_mem = mem.clone();

    let mut mgr =
        OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
    let mut ctl = AdaptController::new(AdaptParams {
        hot_cycles: 1,
        hot_invocations: 1,
        generic_unroll: 1,
        candidate_unrolls: vec![4],
        min_lanes: 4,
        min_batch: 4,
        decision_window: 2,
    });

    // n=1 → 3 total back-edges per call: dominant trip bucket stays below
    // min_batch, so the controller must hold the interpreter tier.
    let sweep: [(usize, usize); 3] = [(1, 3), (3, 4), (n_max, 6)];
    for (n, reps) in sweep {
        let mut a = args.clone();
        a[4] = Val::I(n as i32);
        for _ in 0..reps {
            engine.call_idx(func, &mut mem, &a).unwrap();
            ctl.observe(&mut mgr, &mut engine, func);
            gemm_ref(&mut want_mem, &a, n);
            if outs(&mem, &handles) != outs(&want_mem, &handles) {
                fail_with_diff(
                    "tier-boundary-sweep",
                    format!("n={n} tier={:?} diverged from oracle", ctl.tier(func)),
                );
            }
        }
        match n {
            1 => assert_eq!(ctl.tier(func), Tier::Interpreter, "below min_batch"),
            3 => assert!(
                matches!(ctl.tier(func), Tier::Generic | Tier::Specialized),
                "mid size must offload"
            ),
            _ => assert_eq!(ctl.tier(func), Tier::Specialized, "large size specializes"),
        }
    }
    assert!(
        ctl.transitions(func).len() >= 2,
        "trace must show the tier walk: {:?}",
        ctl.transitions(func)
    );
}

/// Warm-started tier-(N+1) artifacts ≡ cold-compiled ones, end to end
/// through the real manager + stub. The warm path offloads at u=2 and
/// live-respecializes to u=4 — `reconfigure` seeds the u=4 search with
/// the live u=2 placement (incremental placement reuse); the cold path
/// compiles u=4 directly. Both must match the host oracle bit for bit:
/// a placement hint re-times the search, never the artifact's semantics.
#[test]
fn conformance_warm_started_respecialization_matches_cold_compile() {
    use tlo::offload::Reconfig;

    fn run_at(case: &Case, n: usize, unroll: usize, respec_from: Option<usize>) -> Vec<Vec<i32>> {
        let mut engine = Engine::new((case.module)()).expect("module");
        let mut mem = Memory::new();
        let (args, handles) = (case.setup)(&mut mem, n);
        let func = engine.func_index(case.func).expect("func");
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll: respec_from.unwrap_or(unroll),
            ..Default::default()
        });
        mgr.try_offload(&mut engine, func, None).expect("offload");
        if respec_from.is_some() {
            // Unconditional live respecialization: the live artifact's
            // placement warm-starts the tier-(N+1) search.
            let r = mgr.reconfigure(&mut engine, func, unroll, 3, None).expect("respec");
            assert!(matches!(r, Reconfig::Swapped { .. }), "{}: {r:?}", case.name);
            let active = mgr.active(func).expect("artifact live after swap");
            assert_eq!(active.unroll, unroll);
            assert!(
                active.cached.par_stats.is_some(),
                "{}: the respec artifact must carry its compile provenance",
                case.name
            );
        }
        engine.call_idx(func, &mut mem, &args).expect("run");
        outs(&mem, &handles)
    }

    for case in cases() {
        if !matches!(case.name, "gemm" | "syr2k" | "trmm") {
            continue;
        }
        let n = *case.sizes.last().unwrap();
        let want = {
            let mut mem = Memory::new();
            let (args, handles) = (case.setup)(&mut mem, n);
            (case.reference)(&mut mem, &args, n);
            outs(&mem, &handles)
        };
        let cold = run_at(&case, n, 4, None);
        let warm = run_at(&case, n, 4, Some(2));
        if warm != want || cold != want || warm != cold {
            fail_with_diff(
                case.name,
                format!(
                    "warm-vs-cold respec divergence at n={n}: warm==oracle {}, \
                     cold==oracle {}, warm==cold {}",
                    warm == want,
                    cold == want,
                    warm == cold
                ),
            );
        }
    }
}

/// Tiled-plan conformance (the multi-tile lockdown): kernels whose DFGs
/// exceed the grid capacity — previously hard rejections — must offload
/// as multi-tile execution plans and stay bit-identical to the
/// interpreter and the host oracle at every dataset size, on both sim
/// backends. 2mm would be the natural fifth oversized kernel but is
/// multi-SCoP (it never reaches P&R at any size — see `cases()`), so
/// gesummv stands in for it.
#[test]
fn conformance_oversized_kernels_execute_as_multi_tile_plans() {
    use tlo::dfe::grid::Grid;

    fn run_tiled(
        case: &Case,
        n: usize,
        unroll: usize,
        grid: Grid,
        sim_backend: SimBackendChoice,
        lower: bool,
    ) -> (Vec<Vec<i32>>, usize) {
        let mut engine = Engine::new((case.module)()).expect("module");
        let mut mem = Memory::new();
        let (args, handles) = (case.setup)(&mut mem, n);
        let func = engine.func_index(case.func).expect("func");
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll,
            grid,
            sim_backend,
            lower,
            ..Default::default()
        });
        let rec = mgr
            .try_offload(&mut engine, func, None)
            .unwrap_or_else(|e| panic!("{} u{unroll}: tiled offload refused: {e}", case.name));
        assert!(engine.is_patched(func), "{}: stub must be live", case.name);
        engine.call_idx(func, &mut mem, &args).expect("run");
        (outs(&mem, &handles), rec.tiles)
    }

    // Each kernel at an unroll factor whose DFG exceeds the 3x3 grid
    // (9 cells), so the single-tile path would reject it outright.
    let oversized: &[(&str, usize)] =
        &[("gemm", 8), ("trmm", 8), ("syr2k", 4), ("gesummv", 8), ("conv", 1)];
    let grid = Grid::new(3, 3);
    for &(name, unroll) in oversized {
        let case = cases().into_iter().find(|c| c.name == name).expect("case registered");
        for &n in case.sizes {
            let want = {
                let mut mem = Memory::new();
                let (args, handles) = (case.setup)(&mut mem, n);
                (case.reference)(&mut mem, &args, n);
                outs(&mem, &handles)
            };
            let (interp, _) = run_mode(&case, n, None);
            // Auto with lowering on (per-tile lowered batch kernels) and
            // off (the compiled-wave fallback), plus the CycleSim pin.
            let (lowered, tiles_f) =
                run_tiled(&case, n, unroll, grid, SimBackendChoice::Auto, true);
            let (wave, tiles_w) =
                run_tiled(&case, n, unroll, grid, SimBackendChoice::Auto, false);
            let (cycle, tiles_c) =
                run_tiled(&case, n, unroll, grid, SimBackendChoice::CycleSim, true);
            assert!(
                tiles_f > 1,
                "{name} u{unroll}: expected a multi-tile plan, got {tiles_f} tile(s)"
            );
            assert_eq!(tiles_f, tiles_c, "{name}: backend choice must not change the cut");
            assert_eq!(tiles_f, tiles_w, "{name}: the lowering switch must not change the cut");
            let runs = [
                ("interpreter", &interp),
                ("tiled-lowered", &lowered),
                ("tiled-wave", &wave),
                ("tiled-cyclesim", &cycle),
            ];
            for (mode, got) in runs {
                if *got != want {
                    fail_with_diff(
                        name,
                        format!(
                            "oversized {name} u{unroll} n={n} mode {mode} diverges from the oracle"
                        ),
                    );
                }
            }
        }
    }
}

/// Static-verification lockdown (DESIGN.md §11): every artifact the
/// conformance surface installs — single-tile configs and oversized
/// multi-tile plans alike — must re-verify with zero error diagnostics.
/// This is the translation-validation half of conformance: the numeric
/// suites above prove the artifacts compute the right values; this test
/// proves they also satisfy every structural, routing, hazard and plan
/// invariant the verifier re-derives independently of the compiler.
#[test]
fn conformance_artifacts_pass_static_verification() {
    use tlo::analysis::diag::{render_table, Severity};
    use tlo::analysis::verifier::verify_artifact;

    let clean = |name: &str, diags: &[tlo::analysis::diag::Diag]| {
        assert!(
            !diags.iter().any(|d| d.severity == Severity::Error),
            "{name}: installed artifact fails static verification\n{}",
            render_table(diags)
        );
    };

    for case in cases() {
        if !case.offloadable {
            continue;
        }
        let mut engine = Engine::new((case.module)()).expect("module");
        let func = engine.func_index(case.func).expect("func");
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll: case.unroll,
            ..Default::default()
        });
        mgr.try_offload(&mut engine, func, None)
            .unwrap_or_else(|e| panic!("{}: offload refused: {e}", case.name));
        let active = mgr.active(func).expect("artifact live");
        assert!(active.plan.is_none(), "{}: expected a single-tile artifact", case.name);
        clean(case.name, &verify_artifact(&active.cached));
    }
}

/// The multi-tile half of the lockdown: oversized kernels forced through
/// the 3x3 cut (the same matrix as
/// `conformance_oversized_kernels_execute_as_multi_tile_plans`) must
/// produce plans that verify clean — both the provenance-free invariants
/// (spill discipline, per-tile configs, word accounting) and, where the
/// source kernel is available as a bare function, the full provenance
/// re-derivation (positional tile keys, calc conservation, semantic
/// probe against the uncut DFG).
#[test]
fn conformance_oversized_plans_pass_static_verification() {
    use tlo::analysis::diag::{render_table, Severity};
    use tlo::analysis::verifier::{verify_plan, verify_plan_with_provenance};
    use tlo::dfe::grid::Grid;
    use tlo::dfg::extract::extract;
    use tlo::dfg::partition::{partition, TileBudget};
    use tlo::ir::func::Function;

    let clean = |name: &str, diags: &[tlo::analysis::diag::Diag]| {
        assert!(
            !diags.iter().any(|d| d.severity == Severity::Error),
            "{name}: plan fails static verification\n{}",
            render_table(diags)
        );
    };

    let oversized: &[(&str, usize, Option<fn() -> Function>)] = &[
        ("gemm", 8, Some(pb::gemm as fn() -> Function)),
        ("trmm", 8, Some(pb::trmm)),
        ("syr2k", 4, Some(pb::syr2k)),
        ("gesummv", 8, Some(pb::gesummv)),
        ("conv", 1, None), // module-level kernel: provenance-free check only
    ];
    let grid = Grid::new(3, 3);
    for &(name, unroll, build) in oversized {
        let case = cases().into_iter().find(|c| c.name == name).expect("case registered");
        let mut engine = Engine::new((case.module)()).expect("module");
        let func = engine.func_index(case.func).expect("func");
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll,
            grid,
            ..Default::default()
        });
        mgr.try_offload(&mut engine, func, None)
            .unwrap_or_else(|e| panic!("{name} u{unroll}: tiled offload refused: {e}"));
        let active = mgr.active(func).expect("plan live");
        let plan = active.plan.as_ref().unwrap_or_else(|| {
            panic!("{name} u{unroll}: expected a multi-tile plan on the 3x3 grid")
        });
        clean(name, &verify_plan(plan));

        // Re-derive the cut independently (extraction and partitioning
        // are deterministic — P4/P9) and hold the installed plan to it.
        if let Some(build) = build {
            let f = build();
            let an = tlo::analysis::scop::analyze_function(&f);
            let scop = an.scops.first().expect("kernel has a SCoP");
            let off = extract(&f, scop, unroll).expect("kernel extracts");
            let tiled =
                partition(&off.dfg, TileBudget::for_grid(grid)).expect("kernel partitions");
            clean(name, &verify_plan_with_provenance(plan, active.key, &off.dfg, &tiled));
        }
    }
}

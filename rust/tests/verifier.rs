//! Mutation self-test harness for the static artifact verifier
//! (`analysis::verifier`, DESIGN.md §11): a verifier is only worth
//! trusting if it demonstrably *fails* on corrupted artifacts. Each test
//! starts from a valid artifact, asserts the clean baseline (zero
//! diagnostics), applies one mutation from ISSUE 9's matrix — swap a
//! route hop, reorder two schedule slots, re-point a spill, flip a tile
//! key, truncate/corrupt a snapshot — and asserts the *named* pass
//! catches it:
//!
//! | mutation                           | pass |
//! |------------------------------------|------|
//! | dangling DFG edge / dup stream     | V1   |
//! | route hop swap, pad off the border |      |
//! | face double-booking                | V2   |
//! | schedule reorder, timing drift     | V3   |
//! | spill re-point, tile-key flip      | V4   |
//! | snapshot truncation / corruption   | V5   |
//! | lowered-step reorder, prefill      |      |
//! | corruption, output-tap re-point    | V6   |

use std::rc::Rc;

use tlo::analysis::diag::{has_errors, Pass, Severity};
use tlo::analysis::verifier::{
    verify_artifact, verify_config, verify_lowered, verify_offload, verify_plan,
    verify_plan_with_provenance,
};
use tlo::dfe::cache::{dfg_key, spec_key, CachedConfig, ConfigCache, SpecSignature};
use tlo::dfe::config::{fig2_config, GridConfig, IoAssign, OutSrc};
use tlo::dfe::exec::CompiledFabric;
use tlo::dfe::grid::{CellCoord, Dir, Grid};
use tlo::dfe::opcodes::Op;
use tlo::dfe::persist::{load_cache, save_cache, CACHE_FILE};
use tlo::dfe::{tile_key, ExecutionPlan, FuSrc, LoweredKernel, PlanTile};
use tlo::dfg::extract::extract;
use tlo::dfg::partition::{partition, TileBudget, TiledDfg, TileSink, TileSource};
use tlo::par::{place_and_route, ParParams};
use tlo::util::prng::Rng;
use tlo::workloads::polybench;

/// The fig2 artifact (§II's `C = A + 3B + 1` on a 2x2 overlay): the
/// smallest config that exercises pads, routed hops and a 3-FU chain.
fn fig2_artifact() -> CachedConfig {
    let config = fig2_config();
    let image = config.to_image().expect("fig2 lowers");
    let c = CachedConfig::new(config, image, "verifier_fixture".into());
    assert!(c.fabric.is_some(), "fig2 compiles to a wave schedule");
    c
}

/// gemm@u8 cut for a 3x3 overlay and routed tile by tile — the same
/// assembly the serve layer performs (`benches/hotpath.rs` idiom).
fn gemm_tiled_plan() -> (ExecutionPlan, u64, tlo::dfg::graph::Dfg, TiledDfg) {
    let f = polybench::gemm();
    let an = tlo::analysis::scop::analyze_function(&f);
    let scop = an.scops.first().expect("gemm has a SCoP");
    let off = extract(&f, scop, 8).expect("gemm extracts at unroll 8");
    let grid = Grid::new(3, 3);
    let tiled = partition(&off.dfg, TileBudget::for_grid(grid)).expect("gemm@u8 partitions");
    assert!(tiled.n_tiles() > 1, "gemm@u8 must not fit a 3x3 overlay");
    let plan_key = spec_key(dfg_key(&off.dfg), SpecSignature::generic(8));
    let mut tiles = Vec::with_capacity(tiled.n_tiles());
    for (idx, t) in tiled.tiles.iter().enumerate() {
        let res = (0..64u64)
            .find_map(|seed| {
                let mut rng = Rng::new(0x71E5 + seed * 997 + idx as u64);
                place_and_route(&t.dfg, grid, &ParParams::default(), &mut rng).ok()
            })
            .expect("every cut tile routes");
        let image = res.config.to_image().expect("routed tiles lower");
        tiles.push(PlanTile {
            cached: CachedConfig::new(res.config, image, format!("tile{idx}_3x3")),
            sources: t.sources.clone(),
            sinks: t.sinks.clone(),
            key: tile_key(plan_key, idx, dfg_key(&t.dfg)),
        });
    }
    let plan = ExecutionPlan { tiles, n_spills: tiled.n_spills };
    (plan, plan_key, off.dfg.clone(), tiled)
}

fn passes(diags: &[tlo::analysis::diag::Diag]) -> Vec<Pass> {
    diags.iter().filter(|d| d.severity == Severity::Error).map(|d| d.pass).collect()
}

// ------------------------------------------------------------------ V1 --

#[test]
fn v1_catches_duplicate_stream_binding_and_dangling_edge() {
    let f = polybench::gemm();
    let an = tlo::analysis::scop::analyze_function(&f);
    let scop = an.scops.first().expect("gemm has a SCoP");
    let mut off = extract(&f, scop, 2).expect("gemm extracts");
    assert!(verify_offload(&f, &off).is_empty(), "baseline extraction verifies clean");

    // Mutation: re-point a value edge past the end of the node table.
    let n = off.dfg.nodes.len();
    let victim = off
        .dfg
        .nodes
        .iter()
        .position(|nd| !nd.srcs.is_empty())
        .expect("extraction has dependent nodes");
    off.dfg.nodes[victim].srcs[0] = n + 7;
    let diags = verify_offload(&f, &off);
    assert!(passes(&diags).contains(&Pass::V1IrDfg), "dangling edge is V1's: {diags:?}");

    // Mutation: bind the same input stream twice.
    let mut off2 = extract(&f, scop, 2).expect("gemm extracts");
    let dup = off2
        .dfg
        .nodes
        .iter()
        .position(|nd| matches!(nd.kind, tlo::dfg::graph::NodeKind::Input(0)))
        .expect("stream 0 is bound");
    if let tlo::dfg::graph::NodeKind::Input(j) = &mut off2.dfg.nodes[dup].kind {
        *j = 1; // stream 1 now bound twice, stream 0 unbound
    }
    let diags = verify_offload(&f, &off2);
    assert!(passes(&diags).contains(&Pass::V1IrDfg), "dup stream is V1's: {diags:?}");
}

// ------------------------------------------------------------------ V2 --

#[test]
fn v2_catches_a_swapped_route_hop() {
    let mut cfg = fig2_config();
    assert!(verify_config(&cfg).is_empty(), "fig2 baseline verifies clean");
    // Mutation: (1,0)'s FU reads its N face (fed by (0,0)'s routed 3B
    // product); swap the hop to the E face, whose neighbor drives nothing
    // westward.
    let cell = cfg.cell_mut(CellCoord::new(1, 0));
    assert_eq!(cell.fu2, FuSrc::In(Dir::N), "fixture still routes B through N");
    cell.fu2 = FuSrc::In(Dir::E);
    let diags = verify_config(&cfg);
    assert!(passes(&diags).contains(&Pass::V2GridLegality), "route hop is V2's: {diags:?}");
}

#[test]
fn v2_catches_double_booked_faces_and_interior_pads() {
    // Mutation: bind a second input pad onto an already-bound face.
    let mut cfg = fig2_config();
    let first = cfg.inputs[0];
    cfg.inputs.push(IoAssign { cell: first.cell, dir: first.dir, index: 2 });
    let diags = verify_config(&cfg);
    assert!(passes(&diags).contains(&Pass::V2GridLegality), "face reuse is V2's: {diags:?}");

    // Mutation: move the output pad to an interior face.
    let mut cfg = fig2_config();
    cfg.outputs[0] = IoAssign { cell: CellCoord::new(1, 1), dir: Dir::N, index: 0 };
    let diags = verify_config(&cfg);
    assert!(passes(&diags).contains(&Pass::V2GridLegality), "interior pad is V2's: {diags:?}");
}

// ------------------------------------------------------------------ V3 --

#[test]
fn v3_catches_reordered_schedule_slots() {
    let mut cached = fig2_artifact();
    assert!(verify_artifact(&cached).is_empty(), "fig2 artifact verifies clean");
    // Mutation: swap the first and last firings of the 3-FU dependency
    // chain — the first firing now reads a slot its producer defines
    // later.
    let mut fab = CompiledFabric::compile(&cached.config).expect("fig2 compiles");
    let last = fab.n_ops() - 1;
    assert!(last >= 1, "fig2 schedules a multi-op chain");
    fab.swap_schedule_slots(0, last);
    cached.fabric = Some(Rc::new(fab));
    let diags = verify_artifact(&cached);
    assert!(passes(&diags).contains(&Pass::V3WaveHazard), "schedule order is V3's: {diags:?}");
}

#[test]
fn v3_catches_fill_latency_drift() {
    let mut cached = fig2_artifact();
    let mut fab = CompiledFabric::compile(&cached.config).expect("fig2 compiles");
    assert_eq!(fab.fill_latency, 7, "fig2's analytic fill (exec.rs unit tests)");
    fab.set_fill_latency(12);
    cached.fabric = Some(Rc::new(fab));
    let diags = verify_artifact(&cached);
    assert!(passes(&diags).contains(&Pass::V3WaveHazard), "timing drift is V3's: {diags:?}");
}

// ------------------------------------------------------------------ V4 --

#[test]
fn v4_catches_a_repointed_spill() {
    let (mut plan, plan_key, dfg, tiled) = gemm_tiled_plan();
    assert!(verify_plan(&plan).is_empty(), "assembled plan verifies clean");
    assert!(
        verify_plan_with_provenance(&plan, plan_key, &dfg, &tiled).is_empty(),
        "assembled plan verifies clean with provenance"
    );
    // Mutation: re-point the first spill *read* at the last spill slot —
    // whose producer tile is never strictly earlier than every reader.
    let last_slot = plan.n_spills - 1;
    let (ti, si) = plan
        .tiles
        .iter()
        .enumerate()
        .find_map(|(ti, t)| {
            t.sources.iter().position(|s| matches!(s, TileSource::Spill(_))).map(|si| (ti, si))
        })
        .expect("a multi-tile plan reads spills");
    let writer = plan
        .tiles
        .iter()
        .position(|t| t.sinks.contains(&TileSink::Spill(last_slot)))
        .expect("last slot has a writer");
    assert!(writer >= ti, "fixture: last slot's writer must not precede the first reader");
    plan.tiles[ti].sources[si] = TileSource::Spill(last_slot);
    let diags = verify_plan(&plan);
    assert!(passes(&diags).contains(&Pass::V4PlanSoundness), "spill re-point is V4's: {diags:?}");
}

#[test]
fn v4_catches_a_flipped_tile_key() {
    let (mut plan, plan_key, dfg, tiled) = gemm_tiled_plan();
    // Mutation: one flipped provenance bit. Execution semantics are
    // untouched — only the provenance pass can see this.
    plan.tiles[0].key ^= 1;
    assert!(verify_plan(&plan).is_empty(), "provenance-free V4 cannot see a key flip");
    let diags = verify_plan_with_provenance(&plan, plan_key, &dfg, &tiled);
    assert!(passes(&diags).contains(&Pass::V4PlanSoundness), "tile key is V4's: {diags:?}");
}

// ------------------------------------------------------------------ V5 --

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tlo-verifier-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn v5_rejects_truncated_and_corrupted_snapshots() {
    let dir = scratch_dir("v5");
    let mut cache = ConfigCache::new(8);
    cache.insert(0xA1, fig2_artifact());
    let path = save_cache(&cache, &dir).expect("snapshot writes");
    let text = std::fs::read_to_string(&path).expect("snapshot reads back");

    // Mutation: truncate the file mid-entry (drop the `end` terminator
    // and everything after).
    let cut = text.find("\nend").expect("snapshot has a terminator");
    std::fs::write(dir.join(CACHE_FILE), &text[..cut + 1]).expect("rewrite");
    let mut back = ConfigCache::new(8);
    let err = load_cache(&mut back, &dir).expect_err("truncated snapshot must refuse");
    assert!(err.to_string().contains("V5"), "truncation attributes to V5: {err}");
    assert!(back.is_empty());

    // Mutation: byte-valid route corruption — re-point (1,0)'s fu2 from
    // its N face (token i0) to the E face. Every line still parses; the
    // artifact no longer lowers/verifies, and V5 must refuse the load.
    let corrupt = text.replace("i3 i0 -", "i3 i1 -");
    assert_ne!(corrupt, text, "fixture line found and flipped");
    std::fs::write(dir.join(CACHE_FILE), corrupt).expect("rewrite");
    let mut back = ConfigCache::new(8);
    let err = load_cache(&mut back, &dir).expect_err("corrupt snapshot must refuse");
    assert!(err.to_string().contains("V5"), "semantic corruption attributes to V5: {err}");
    assert!(back.is_empty(), "nothing from the corrupt snapshot may be served");

    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------------ V6 --

/// A 1x2 pipeline whose first stage is output-tapped: the tap is a
/// fusion barrier, so the lowered kernel keeps TWO ordered steps
/// (Add then Mul reading the Add's slot) — the smallest fixture where a
/// step reorder is a genuine scoreboard violation, not just a
/// fingerprint change.
fn tapped_pipeline() -> (CompiledFabric, LoweredKernel) {
    let mut cfg = GridConfig::empty(Grid::new(1, 2));
    let c0 = CellCoord::new(0, 0);
    let c1 = CellCoord::new(0, 1);
    cfg.inputs.push(IoAssign { cell: c0, dir: Dir::W, index: 0 });
    {
        let cell = cfg.cell_mut(c0);
        cell.op = Some(Op::Add);
        cell.fu1 = FuSrc::In(Dir::W);
        cell.fu2 = FuSrc::Const(5);
        cell.out[Dir::E.index()] = OutSrc::Fu; // feeds the Mul
        cell.out[Dir::S.index()] = OutSrc::Fu; // border tap
    }
    {
        let cell = cfg.cell_mut(c1);
        cell.op = Some(Op::Mul);
        cell.fu1 = FuSrc::In(Dir::W);
        cell.fu2 = FuSrc::Const(3);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    cfg.outputs.push(IoAssign { cell: c0, dir: Dir::S, index: 0 });
    cfg.outputs.push(IoAssign { cell: c1, dir: Dir::E, index: 1 });
    let fab = CompiledFabric::compile(&cfg).expect("tapped pipeline compiles");
    let k = LoweredKernel::lower(&fab);
    assert_eq!(k.n_steps(), 2, "the tap must block fusion, leaving two ordered steps");
    (fab, k)
}

#[test]
fn v6_catches_reordered_lowered_steps() {
    let (fab, mut k) = tapped_pipeline();
    assert!(!has_errors(&verify_lowered(&fab, &k)), "baseline lowered kernel verifies clean");
    // Mutation: swap the two steps — the Mul now reads the Add's slot
    // before the Add defines it (and the stored fingerprint no longer
    // matches the structure).
    k.swap_steps(0, 1);
    let diags = verify_lowered(&fab, &k);
    assert!(passes(&diags).contains(&Pass::V6LoweredKernel), "step order is V6's: {diags:?}");
}

#[test]
fn v6_catches_corrupted_prefill_constants() {
    let (fab, mut k) = tapped_pipeline();
    // Mutation: bump one prefill constant by 1. The structure is intact;
    // only the constant re-derivation (and the probe) can see it.
    k.corrupt_prefill();
    let diags = verify_lowered(&fab, &k);
    assert!(passes(&diags).contains(&Pass::V6LoweredKernel), "prefill drift is V6's: {diags:?}");
}

#[test]
fn v6_catches_a_repointed_output_tap() {
    let (fab, mut k) = tapped_pipeline();
    // Mutation: re-point the first output tap at the zero slot.
    k.retarget_out();
    let diags = verify_lowered(&fab, &k);
    assert!(passes(&diags).contains(&Pass::V6LoweredKernel), "tap re-point is V6's: {diags:?}");
}

#[test]
fn v6_runs_inside_artifact_verification() {
    // The artifact-level entry point must route lowered-kernel corruption
    // to V6 — this is what cache verify-on-insert, `tlo lint` and the
    // snapshot gate actually call.
    let mut cached = fig2_artifact();
    assert!(verify_artifact(&cached).is_empty(), "fig2 artifact verifies clean");
    let mut k = (**cached.lowered.as_ref().expect("fig2 lowers")).clone();
    k.retarget_out();
    cached.lowered = Some(Rc::new(k));
    let diags = verify_artifact(&cached);
    assert!(passes(&diags).contains(&Pass::V6LoweredKernel), "artifact V6: {diags:?}");

    // A compiled fabric with the lowered kernel dropped is advisory-only:
    // the serve path falls back to the wave executor, so V6 warns rather
    // than errors.
    let mut cached = fig2_artifact();
    cached.lowered = None;
    let diags = verify_artifact(&cached);
    assert!(!has_errors(&diags), "missing lowered kernel must not be an error");
    assert!(
        diags.iter().any(|d| d.pass == Pass::V6LoweredKernel && d.severity == Severity::Warning),
        "missing lowered kernel warns under V6: {diags:?}"
    );
}

// ----------------------------------------------- clean-fleet invariants --

#[test]
fn routed_fuzz_artifacts_verify_clean_and_deterministically() {
    // The P12 property in miniature (full sweep in tests/proptests.rs):
    // everything the Las-Vegas P&R routes must verify clean, twice, with
    // byte-identical diagnostics.
    let grid = Grid::new(6, 6);
    let mut routed = 0;
    for case in 0..20u64 {
        let mut rng = Rng::new(0x5EED_0 + case);
        let dfg = {
            // Reuse the exec_fuzz generator shape inline: a few inputs, a
            // short chain of real ops.
            let mut g = tlo::dfg::graph::Dfg::new();
            let a = g.input(0);
            let b = g.input(1);
            let mut pool = vec![a, b, g.constant(3)];
            for _ in 0..(2 + rng.below(5)) {
                let x = pool[rng.below(pool.len())];
                let y = pool[rng.below(pool.len())];
                let op = [
                    tlo::dfe::opcodes::Op::Add,
                    tlo::dfe::opcodes::Op::Mul,
                    tlo::dfe::opcodes::Op::Sub,
                    tlo::dfe::opcodes::Op::Max,
                ][rng.below(4)];
                pool.push(g.calc(op, x, y));
            }
            let last = *pool.last().expect("pool is non-empty");
            g.output(0, last);
            g
        };
        let Ok(res) = place_and_route(&dfg, grid, &ParParams::default(), &mut rng) else {
            continue;
        };
        routed += 1;
        let image = res.config.to_image().expect("routed configs lower");
        let cached = CachedConfig::new(res.config, image, format!("fuzz{case}"));
        let first = verify_artifact(&cached);
        assert!(
            !has_errors(&first),
            "case {case}: routed artifact must verify error-free\n{}",
            tlo::analysis::diag::render_table(&first)
        );
        assert_eq!(first, verify_artifact(&cached), "case {case}: verify must be deterministic");
    }
    assert!(routed >= 10, "fuzz sweep must route a meaningful sample, got {routed}");
}

#[test]
fn verify_on_insert_is_transparent_for_valid_artifacts() {
    // The debug-build sanitizer hooks must accept everything the real
    // pipeline produces — entries and multi-tile plans alike.
    let mut cache = ConfigCache::new(64);
    cache.insert(1, fig2_artifact());
    let (plan, plan_key, _, _) = gemm_tiled_plan();
    cache.insert_plan(plan_key, plan);
    assert!(cache.contains(1) && cache.contains_plan(plan_key));
}

//! Compile-service properties (tentpole lockdown for the racing
//! seed-portfolio P&R and incremental placement reuse):
//!
//!   PS1  portfolio determinism: a fixed `(base seed, K)` yields a
//!        bit-identical winning `GridConfig`/placement across runs and
//!        across worker-thread counts (the race is decided on
//!        deterministic step counts, not wall time);
//!   PS2  warm-start soundness: a tier-N placement seeding the tier-N+1
//!        search yields an artifact that evaluates bit-identically to the
//!        cold-compiled one on random inputs;
//!   PS3  a poisoned warm seed (incompatible grid / bogus node ids) falls
//!        back to a cold search instead of erroring;
//!   PS4  background service: jobs land with the same deterministic
//!        winner a foreground race produces, and failed jobs surface as
//!        errors rather than hanging.

use tlo::analysis::scop::analyze_function;
use tlo::dfe::grid::{CellCoord, Grid};
use tlo::dfg::extract::extract;
use tlo::par::{
    derive_seed, place_and_route_portfolio, place_and_route_seeded, CompileJob,
    CompileService, LapOutcome, ParParams, ParSeed, PortfolioParams,
};
use tlo::util::prng::Rng;
use tlo::workloads::polybench;
use tlo::workloads::video::conv_func;

/// The §IV-C conv DFG (17 in / 1 out / 16 calc) at unroll `u`.
fn conv_dfg(u: usize) -> tlo::dfg::graph::Dfg {
    let f = conv_func();
    let an = analyze_function(&f);
    extract(&f, &an.scops[0], u).expect("conv extracts").dfg
}

fn gemm_dfg(u: usize) -> tlo::dfg::graph::Dfg {
    let f = polybench::gemm();
    let an = analyze_function(&f);
    extract(&f, &an.scops[0], u).expect("gemm extracts").dfg
}

/// Differential eval: the routed image must agree with the DFG semantics
/// on random inputs.
fn assert_image_matches(dfg: &tlo::dfg::graph::Dfg, image: &tlo::dfe::image::ExecImage, seed: u64) {
    let n_in = dfg.max_input_index().map(|m| m + 1).unwrap_or(0);
    let mut rng = Rng::new(seed);
    for trial in 0..16 {
        let inputs: Vec<i32> =
            (0..n_in).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
        let want = dfg.eval(&inputs).expect("dfg eval");
        assert_eq!(image.eval_scalar(&inputs), want, "trial {trial}");
    }
}

#[test]
fn ps1_portfolio_winner_bit_identical_across_runs_and_thread_counts() {
    let dfg = conv_dfg(1);
    let grid = Grid::new(8, 8);
    let params = ParParams::default();
    for spec_key in [0xAAAAu64, 0x1234_5678, 9] {
        let run = |threads: usize| {
            place_and_route_portfolio(
                &dfg,
                grid,
                &params,
                &ParSeed::Cold,
                &PortfolioParams { k: 4, base_seed: spec_key, threads },
            )
            .expect("conv routes on 8x8")
        };
        let a = run(4);
        let b = run(4);
        let c = run(1); // sequential: scheduling-independence witness
        assert_eq!(a.entrant, b.entrant, "key {spec_key:#x}");
        assert_eq!(a.result.config, b.result.config, "key {spec_key:#x}");
        assert_eq!(a.result.placement, b.result.placement, "key {spec_key:#x}");
        assert_eq!(a.entrant, c.entrant, "key {spec_key:#x}: threads changed the winner");
        assert_eq!(a.result.config, c.result.config, "key {spec_key:#x}");
        assert_eq!(a.seed, derive_seed(spec_key, a.entrant));
        assert_image_matches(&dfg, &a.result.image, spec_key ^ 1);
        // Every lap is accounted for, and the winner's lap is Routed.
        assert_eq!(a.laps.len(), 4);
        assert_eq!(a.laps[a.entrant].outcome, LapOutcome::Routed);
        assert_eq!(a.laps[a.entrant].steps, a.result.stats.search_steps());
    }
}

#[test]
fn ps2_warm_started_tier_artifact_matches_cold_compiled() {
    // Tier N (u=2) cold, then tier N+1 (u=4) warm-started from N's
    // placement: the warm artifact must evaluate identically to a cold
    // u=4 compile (placement is a hint; semantics come from the DFG).
    let grid = Grid::new(12, 12);
    let params = ParParams::default();
    let tier2 = place_and_route_portfolio(
        &gemm_dfg(2),
        grid,
        &params,
        &ParSeed::Cold,
        &PortfolioParams { k: 2, base_seed: 21, threads: 2 },
    )
    .expect("gemm u2 routes");
    let dfg4 = gemm_dfg(4);
    let warm = place_and_route_portfolio(
        &dfg4,
        grid,
        &params,
        &ParSeed::Warm(tier2.result.placement.clone()),
        &PortfolioParams { k: 2, base_seed: 42, threads: 2 },
    )
    .expect("warm u4 routes");
    let cold = place_and_route_portfolio(
        &dfg4,
        grid,
        &params,
        &ParSeed::Cold,
        &PortfolioParams { k: 2, base_seed: 42, threads: 2 },
    )
    .expect("cold u4 routes");
    assert_image_matches(&dfg4, &warm.result.image, 7);
    assert_image_matches(&dfg4, &cold.result.image, 7);
    // Same semantics regardless of how the search was seeded.
    let n_in = dfg4.max_input_index().unwrap() + 1;
    let mut rng = Rng::new(99);
    for _ in 0..8 {
        let inputs: Vec<i32> =
            (0..n_in).map(|_| rng.range_i64(-500, 500) as i32).collect();
        assert_eq!(
            warm.result.image.eval_scalar(&inputs),
            cold.result.image.eval_scalar(&inputs),
            "warm and cold artifacts diverge semantically"
        );
    }
}

#[test]
fn ps3_poisoned_warm_seeds_fall_back_to_cold() {
    let dfg = conv_dfg(1);
    let params = ParParams::default();
    // (a) A placement carrying cells of a larger overlay (e.g. (11,11)
    // from a 12x12 artifact) used on an 8x8 grid: off-grid cells poison
    // the seed wholesale and the search runs cold.
    let off_grid: Vec<(usize, CellCoord)> = vec![(0, CellCoord::new(11, 11))];
    let poisoned = place_and_route_seeded(
        &dfg,
        Grid::new(8, 8),
        &params,
        &mut Rng::new(3),
        &ParSeed::Warm(off_grid),
        None,
    )
    .expect("poisoned seed must fall back to cold");
    assert_eq!(poisoned.stats.warm_placed, 0);
    assert_image_matches(&dfg, &poisoned.image, 17);
    // (b) Bogus node ids (beyond the DFG) are skipped pair by pair.
    let bogus = ParSeed::Warm(vec![(9999, CellCoord::new(0, 0)), (10_000, CellCoord::new(1, 1))]);
    let res = place_and_route_seeded(
        &dfg,
        Grid::new(8, 8),
        &params,
        &mut Rng::new(4),
        &bogus,
        None,
    )
    .expect("bogus node ids must be skipped, not fatal");
    assert_eq!(res.stats.warm_placed, 0);
    assert_image_matches(&dfg, &res.image, 18);
}

#[test]
fn ps4_service_jobs_land_with_the_foreground_winner() {
    let dfg = conv_dfg(1);
    let grid = Grid::new(8, 8);
    let mut svc = CompileService::new(3);
    let keys = [0x100u64, 0x200, 0x300, 0x400];
    for &key in &keys {
        svc.submit(CompileJob {
            key,
            base_seed: key,
            dfg: dfg.clone(),
            grid,
            params: ParParams::default(),
            portfolio: 3,
            warm: ParSeed::Cold,
            priority: 0,
        });
    }
    let mut done = Vec::new();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    while done.len() < keys.len() && std::time::Instant::now() < deadline {
        if let Some(d) = svc.recv_timeout(std::time::Duration::from_millis(250)) {
            done.push(d);
        }
    }
    assert_eq!(done.len(), keys.len(), "every job must land");
    for d in done {
        let o = d.outcome.expect("conv routes");
        let fg = place_and_route_portfolio(
            &dfg,
            grid,
            &ParParams::default(),
            &ParSeed::Cold,
            &PortfolioParams { k: 3, base_seed: d.key, threads: 1 },
        )
        .unwrap();
        assert_eq!(o.result.config, fg.result.config, "key {:#x}", d.key);
        assert_eq!(o.entrant, fg.entrant, "key {:#x}", d.key);
        assert_image_matches(&dfg, &o.result.image, d.key);
    }
}

#[test]
fn ps4b_unroutable_jobs_surface_errors_not_hangs() {
    // 16 calc nodes can never fit a 2x2 grid: the job must come back as
    // an error (TooLarge) instead of hanging or panicking the worker.
    let dfg = conv_dfg(1);
    let mut svc = CompileService::new(1);
    svc.submit(CompileJob {
        key: 1,
        base_seed: 1,
        dfg,
        grid: Grid::new(2, 2),
        params: ParParams::default(),
        portfolio: 2,
        warm: ParSeed::Cold,
        priority: 0,
    });
    let d = svc
        .recv_timeout(std::time::Duration::from_secs(30))
        .expect("failure must still complete the job");
    assert!(d.outcome.is_err(), "2x2 cannot hold 16 calc nodes");
}

//! Chaos suite for the fleet layer (`offload::fleet` + `transport::net`):
//!   F1  one fault seed replays an entire chaos run bit-for-bit —
//!       identical counters, per-node traffic, makespan and outputs;
//!   F2  duplicated and reordered result datagrams never double-apply
//!       (the idempotency ledger absorbs every extra copy);
//!   F3  a crash-prone node trips its circuit breaker and the fleet keeps
//!       serving through the healthy node (flaky nodes lose placements);
//!   F4  total loss degrades every request to the local shard fabric and
//!       the output still matches the interpreter oracle bit-for-bit;
//!   F5  lossy and healthy runs produce bit-identical outputs — faults
//!       cost retries and latency, never numerics;
//!   F6  admission backpressure defers rather than overloads a saturated
//!       healthy fleet, and every deferred request still completes;
//!   F7  the ledger arithmetic is internally consistent — every remote
//!       request applies once or degrades once, per-tenant counters sum
//!       to the fleet counters, per-node serve counts sum to the ledger;
//!   F8  a tenant with no fabric path at all (multi-SCoP function, never
//!       offloaded) serves on the software tier and the fleet report
//!       surfaces it gracefully — no unwrap panic on the missing offload
//!       state, correct software-tier row, populated latency percentiles.

use tlo::offload::fleet::{FleetCounters, FleetParams, FleetReport, FleetServer};
use tlo::offload::server::{polybench_mix, run_single_tenant, ServeParams, TenantSpec};
use tlo::transport::{FaultProfile, NetParams};

fn serve_params() -> ServeParams {
    ServeParams {
        shards: 2,
        // Offload economics are not under test; keep tenants patched.
        rollback_window: u64::MAX,
        ..Default::default()
    }
}

fn fleet_params(fault: FaultProfile) -> FleetParams {
    FleetParams {
        nodes: 3,
        net: NetParams { fault, ..NetParams::lan_like() },
        fault_seed: 0xC0FFEE,
        ..Default::default()
    }
}

fn run_fleet(
    fleet: FleetParams,
    specs: Vec<TenantSpec>,
    requests: u64,
) -> (FleetReport, Vec<Vec<Vec<i32>>>) {
    let mut server = FleetServer::new(serve_params(), fleet, specs).expect("fleet server");
    let report = server.run(requests);
    let outs = (0..server.n_tenants()).map(|i| server.tenant_outputs(i)).collect();
    (report, outs)
}

fn node_counters(report: &FleetReport) -> Vec<(u64, u64, tlo::transport::NetStats)> {
    report.nodes.iter().map(|n| (n.served, n.breaker_opens, n.net)).collect()
}

#[test]
fn f1_fault_schedules_replay_from_one_seed() {
    let fault = FaultProfile { drop: 0.2, dup: 0.2, reorder: 0.2, jitter: 0.3, crash: 0.05 };
    let (ra, outs_a) = run_fleet(fleet_params(fault), polybench_mix(4), 8);
    let (rb, outs_b) = run_fleet(fleet_params(fault), polybench_mix(4), 8);
    assert_eq!(ra.counters, rb.counters, "reliability counters diverged across replays");
    assert_eq!(node_counters(&ra), node_counters(&rb), "per-node schedules diverged");
    assert_eq!(ra.serve.makespan, rb.serve.makespan, "virtual time diverged");
    assert_eq!(outs_a, outs_b, "numerics diverged across replays");
    // The chaos run must actually have exercised the fault machinery.
    assert!(ra.counters.retries > 0, "lossy profile produced no retries: {:?}", ra.counters);
    // A different seed draws a different schedule (same workload).
    let mut other = fleet_params(fault);
    other.fault_seed = 0xBEEF;
    let (rc, outs_c) = run_fleet(other, polybench_mix(4), 8);
    assert_ne!(
        node_counters(&ra),
        node_counters(&rc),
        "distinct seeds must draw distinct fault schedules"
    );
    assert_eq!(outs_a, outs_c, "the seed may only move time, never numerics");
}

#[test]
fn f2_duplicates_and_reorders_never_double_apply() {
    let fault = FaultProfile { dup: 0.5, reorder: 0.5, ..FaultProfile::healthy() };
    let (report, outs) = run_fleet(fleet_params(fault), polybench_mix(4), 6);
    let c = &report.counters;
    assert!(c.remote_requests > 0, "mix must offload remotely");
    // No loss: every remote request delivers on its first send and
    // applies exactly once.
    assert_eq!(c.retries, 0);
    assert_eq!(c.applied_results, c.remote_requests, "one application per invocation");
    // Every duplicated result datagram the links produced was absorbed by
    // the idempotency ledger, and reordered arrivals were keyed in.
    let link_dups: u64 = report.nodes.iter().map(|n| n.net.duplicated).sum();
    let link_reord: u64 = report.nodes.iter().map(|n| n.net.reordered).sum();
    assert!(link_dups > 0, "dup=0.5 produced no duplicates");
    assert!(link_reord > 0, "reorder=0.5 produced no reorders");
    assert_eq!(c.dup_suppressed, link_dups, "ledger must absorb every duplicate");
    assert_eq!(c.reordered_absorbed, link_reord);
    // And none of it touched numerics.
    for (i, spec) in polybench_mix(4).iter().enumerate() {
        let want = run_single_tenant(spec, 6).expect("oracle");
        assert_eq!(outs[i], want, "tenant {} diverged under dup/reorder", spec.name);
    }
}

#[test]
fn f3_breaker_trips_on_crashy_node_and_fleet_keeps_serving() {
    let mut fleet = fleet_params(FaultProfile::healthy());
    fleet.nodes = 2;
    // Node 0 crashes constantly; node 1 is healthy.
    fleet.node_faults =
        vec![FaultProfile { crash: 0.9, ..FaultProfile::healthy() }, FaultProfile::healthy()];
    let (report, outs) = run_fleet(fleet, polybench_mix(4), 8);
    let crashy = &report.nodes[0];
    let healthy = &report.nodes[1];
    assert!(
        crashy.breaker_opens >= 1,
        "crash-prone node must trip its breaker: {crashy:?}"
    );
    assert!(healthy.breaker_opens == 0, "healthy node must stay closed: {healthy:?}");
    assert!(
        healthy.served > crashy.served,
        "flaky node must lose placements: {} vs {}",
        healthy.served,
        crashy.served
    );
    // The fleet as a whole absorbed the crashes: every remote request
    // still completed somewhere (remote or degraded-local), numerics
    // intact.
    let c = &report.counters;
    assert_eq!(c.applied_results + c.fallback_local, c.remote_requests);
    for (i, spec) in polybench_mix(4).iter().enumerate() {
        let want = run_single_tenant(spec, 8).expect("oracle");
        assert_eq!(outs[i], want, "tenant {} diverged under node crashes", spec.name);
    }
}

#[test]
fn f4_total_loss_degrades_to_local_fabric_bit_identically() {
    let fault = FaultProfile { drop: 1.0, ..FaultProfile::healthy() };
    let (report, outs) = run_fleet(fleet_params(fault), polybench_mix(4), 6);
    let c = &report.counters;
    assert!(c.remote_requests > 0);
    assert_eq!(c.applied_results, 0, "nothing can deliver under drop=1.0");
    assert_eq!(
        c.fallback_local, c.remote_requests,
        "every remote request must degrade to the local shard fabric"
    );
    assert!(c.retries > 0, "the retry budget must be spent before degrading");
    let executed: u64 = report.serve.shards.iter().map(|s| s.executed).sum();
    assert_eq!(executed, c.fallback_local, "local shards absorbed the degraded load");
    for (i, spec) in polybench_mix(4).iter().enumerate() {
        let want = run_single_tenant(spec, 6).expect("oracle");
        assert_eq!(outs[i], want, "tenant {} diverged under total loss", spec.name);
    }
}

#[test]
fn f5_lossy_run_is_bit_identical_to_healthy_run() {
    let (healthy, outs_h) = run_fleet(fleet_params(FaultProfile::healthy()), polybench_mix(5), 5);
    let lossy_profile =
        FaultProfile { drop: 0.3, dup: 0.2, reorder: 0.2, jitter: 0.5, crash: 0.1 };
    let (lossy, outs_l) = run_fleet(fleet_params(lossy_profile), polybench_mix(5), 5);
    assert_eq!(outs_h, outs_l, "faults may cost time, never correctness");
    assert_eq!(healthy.serve.total_elements, lossy.serve.total_elements);
    assert_eq!(healthy.counters.retries, 0, "healthy fleet never retries");
    assert!(lossy.counters.retries > 0, "lossy fleet must have retried");
    assert!(
        lossy.serve.makespan > healthy.serve.makespan,
        "faults must cost virtual time: lossy {:?} vs healthy {:?}",
        lossy.serve.makespan,
        healthy.serve.makespan
    );
}

#[test]
fn f6_backpressure_defers_but_completes_everything() {
    let mut fleet = fleet_params(FaultProfile::healthy());
    fleet.nodes = 1;
    fleet.node_depth = 1;
    let requests = 5;
    let specs = polybench_mix(4);
    let n = specs.len() as u64;
    let (report, outs) = run_fleet(fleet, specs.clone(), requests);
    let c = &report.counters;
    assert!(
        c.deferred > 0,
        "one node at depth 1 under 4 tenants must defer: {c:?}"
    );
    assert_eq!(report.serve.total_requests, n * requests, "deferred work must complete");
    assert_eq!(c.applied_results, c.remote_requests, "no remote request lost to deferral");
    assert_eq!(c.fallback_local, 0, "backpressure defers, it does not degrade");
    for (i, spec) in specs.iter().enumerate() {
        let want = run_single_tenant(spec, requests).expect("oracle");
        assert_eq!(outs[i], want, "tenant {} diverged under backpressure", spec.name);
    }
}

#[test]
fn f7_counters_are_internally_consistent() {
    // Cross-check the ledger arithmetic under a mixed profile: every
    // remote request either applied remotely or degraded locally, and the
    // per-tenant counters in the serve report sum to the fleet counters.
    let fault = FaultProfile { drop: 0.25, dup: 0.25, reorder: 0.1, jitter: 0.2, crash: 0.05 };
    let (report, _) = run_fleet(fleet_params(fault), polybench_mix(4), 8);
    let c: FleetCounters = report.counters;
    assert_eq!(c.applied_results + c.fallback_local, c.remote_requests);
    let t_remote: u64 = report.serve.tenants.iter().map(|t| t.remote_served).sum();
    let t_retries: u64 = report.serve.tenants.iter().map(|t| t.retries).sum();
    let t_local: u64 = report.serve.tenants.iter().map(|t| t.fallback_local).sum();
    let t_soft: u64 = report.serve.tenants.iter().map(|t| t.fallback_software).sum();
    assert_eq!(t_remote, c.applied_results);
    assert_eq!(t_retries, c.retries);
    assert_eq!(t_local, c.fallback_local);
    assert_eq!(t_soft, c.fallback_software);
    let node_served: u64 = report.nodes.iter().map(|n| n.served).sum();
    assert_eq!(node_served, c.applied_results, "node serve counts match the ledger");
}

#[test]
fn f8_never_offloaded_tenant_reports_gracefully_on_the_software_tier() {
    use tlo::ir::func::Module;
    use tlo::jit::interp::{Memory, Val};
    use tlo::workloads::polybench;

    // atax has two loop nests: patching the whole function would drop the
    // second, so it is structurally rejected at admission and serves on
    // the interpreter for the whole run — its offload and runtime-state
    // slots stay `None`, which is exactly what used to feed the report
    // collector's unwraps.
    fn atax_module() -> Module {
        let mut m = Module::new();
        m.add(polybench::atax());
        m
    }
    fn atax_setup(mem: &mut Memory) -> Vec<Val> {
        let n = 8usize;
        let ha = mem.from_i32(&(0..n * n).map(|i| (i as i32 % 5) - 2).collect::<Vec<_>>());
        let hx = mem.from_i32(&(0..n).map(|i| i as i32 - 3).collect::<Vec<_>>());
        let hy = mem.alloc_i32(n);
        let htmp = mem.alloc_i32(n);
        vec![Val::P(ha), Val::P(hx), Val::P(hy), Val::P(htmp), Val::I(n as i32)]
    }
    fn atax_outs(args: &[Val]) -> Vec<u32> {
        vec![args[2].as_ptr(), args[3].as_ptr()]
    }
    let atax = TenantSpec {
        name: "atax-soft".into(),
        module: atax_module,
        func: "atax",
        unroll: 2,
        setup: atax_setup,
        refresh: None,
        outputs: atax_outs,
        priority: 1,
    };
    let requests = 5u64;
    let mut specs = polybench_mix(2);
    specs.push(atax.clone());
    let (report, outs) =
        run_fleet(fleet_params(FaultProfile::healthy()), specs.clone(), requests);

    let row = report
        .serve
        .tenants
        .iter()
        .find(|t| t.name == "atax-soft")
        .expect("software tenant must appear in the fleet report");
    assert!(!row.offloaded, "atax must not offload: {row:?}");
    assert_eq!(row.requests, requests, "software tier must serve the full quota");
    assert_eq!(row.fallback_software, requests, "every request rode the interpreter");
    assert_eq!(row.remote_served, 0);
    assert_eq!(row.shed, 0, "no SLO configured, nothing sheds");
    assert!(row.reject.as_deref().unwrap_or("").contains("SCoP"), "{row:?}");
    // Tail observability covers the software tier too.
    assert!(row.p50_secs > 0.0, "software requests must land in the histogram");
    assert!(row.p50_secs <= row.p95_secs && row.p95_secs <= row.p99_secs);
    // The offloadable co-tenants were not disturbed, and the software
    // tenant's numerics match the oracle.
    for (i, spec) in specs.iter().enumerate() {
        let want = run_single_tenant(spec, requests).expect("oracle");
        assert_eq!(outs[i], want, "tenant {} diverged", spec.name);
    }
    // Display paths (serve + fleet) must also survive the None state.
    let rendered = format!("{report}");
    assert!(rendered.contains("atax-soft"), "report display must include the tenant");
}

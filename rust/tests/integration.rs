//! Integration tests: the full pipeline (JIT → monitor → analysis → DFG →
//! P&R → DFE → rollback) composed end to end on the sim backend.

use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::Ty;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::offload::{OffloadManager, OffloadParams, RejectReason};
use tlo::profile::{Monitor, MonitorParams};
use tlo::transport::PcieParams;
use tlo::workloads::polybench;
use tlo::workloads::video;

/// A module with a hot offloadable kernel and a cold fp one.
fn mixed_module() -> Module {
    let mut m = Module::new();
    // hot: saxpy-ish integer kernel.
    let mut b = FuncBuilder::new("hot", &[("Y", Ty::Ptr), ("X", Ty::Ptr), ("n", Ty::I32)]);
    let (y, x, n) = (b.param(0), b.param(1), b.param(2));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let xv = b.load(Ty::I32, x, i);
        let c5 = b.const_i32(5);
        let t = b.mul(xv, c5);
        let yv = b.load(Ty::I32, y, i);
        let s = b.add(yv, t);
        b.store(Ty::I32, y, i, s);
    });
    m.add(b.ret(None));
    // cold: fp kernel, never offloadable.
    let mut b = FuncBuilder::new("coldfp", &[("A", Ty::Ptr), ("n", Ty::I32)]);
    let (a, n) = (b.param(0), b.param(1));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let v = b.load(Ty::F32, a, i);
        let w = b.fadd(v, v);
        b.store(Ty::F32, a, i, w);
    });
    m.add(b.ret(None));
    m
}

#[test]
fn monitor_analysis_offload_pipeline() {
    let mut engine = Engine::new(mixed_module()).unwrap();
    let mut mem = Memory::new();
    let n = 4096;
    let hy = mem.alloc_i32(n);
    let hx = mem.from_i32(&(0..n as i32).collect::<Vec<_>>());
    let hf = mem.alloc_f32(16);

    // Drive both functions; the monitor must flag only `hot`.
    let mut monitor = Monitor::new(MonitorParams::default());
    for _ in 0..4 {
        engine.call("hot", &mut mem, &[Val::P(hy), Val::P(hx), Val::I(n as i32)]).unwrap();
        engine.call("coldfp", &mut mem, &[Val::P(hf), Val::I(16)]).unwrap();
    }
    let hotspots = monitor.sample(&engine);
    assert_eq!(hotspots.len(), 1);
    assert_eq!(hotspots[0].name, "hot");

    // Offload the hotspot; fp kernel must be rejected.
    let mut mgr = OffloadManager::new(OffloadParams {
        min_dfg_nodes: 1,
        unroll: 4,
        ..Default::default()
    });
    let hot = engine.func_index("hot").unwrap();
    let cold = engine.func_index("coldfp").unwrap();
    mgr.try_offload(&mut engine, hot, None).expect("hot offloads");
    let err = mgr.try_offload(&mut engine, cold, None).unwrap_err();
    assert!(matches!(err, RejectReason::Illegal(ref s) if s.contains("fp")), "{err}");

    // Numerics preserved through the patched path.
    let before = mem.i32s(hy).to_vec();
    engine.call("hot", &mut mem, &[Val::P(hy), Val::P(hx), Val::I(n as i32)]).unwrap();
    for i in 0..n {
        assert_eq!(mem.i32s(hy)[i], before[i].wrapping_add(5 * i as i32));
    }
}

#[test]
fn offloadable_polybench_kernels_run_correctly_when_offloaded() {
    // gemm end-to-end: software result == offloaded result.
    let mut m = Module::new();
    m.add(polybench::gemm());
    let n = 12usize;
    let run = |offload: bool| -> Vec<i32> {
        let mut engine = Engine::new(m.clone()).unwrap();
        let mut mem = Memory::new();
        let a: Vec<i32> = (0..n * n).map(|i| (i as i32 % 13) - 6).collect();
        let b: Vec<i32> = (0..n * n).map(|i| (i as i32 % 7) - 3).collect();
        let (hc, ha, hb) = (mem.alloc_i32(n * n), mem.from_i32(&a), mem.from_i32(&b));
        let args =
            [Val::P(hc), Val::P(ha), Val::P(hb), Val::I(2), Val::I(n as i32)];
        engine.call("gemm", &mut mem, &args).unwrap();
        if offload {
            let mut mgr = OffloadManager::new(OffloadParams {
                min_dfg_nodes: 1,
                unroll: 4,
                ..Default::default()
            });
            let f = engine.func_index("gemm").unwrap();
            mgr.try_offload(&mut engine, f, None).expect("gemm offloads");
            mem.i32s_mut(hc).fill(0);
            engine.call("gemm", &mut mem, &args).unwrap();
        }
        mem.i32s(hc).to_vec()
    };
    assert_eq!(run(false), run(true), "gemm offloaded vs software");
}

#[test]
fn video_pipeline_fps_shape_matches_paper() {
    // E4 shape: with the tagged protocol, offloaded < software fps;
    // with the packed protocol the offload path improves substantially.
    let fps = |pcie: PcieParams| -> (f64, f64) {
        let mut engine = Engine::new(video::video_module()).unwrap();
        let mut mem = Memory::new();
        let (out, inp, coef) = video::alloc_pipeline(&mut mem);
        let mut src = video::FrameSource::new();
        let mut frame = vec![0i32; video::FRAME_W * video::FRAME_H];
        let func = engine.func_index("conv").unwrap();
        for _ in 0..2 {
            src.next_frame(&mut frame);
            mem.i32s_mut(inp).copy_from_slice(&frame);
            engine.call("conv", &mut mem, &video::conv_args(out, inp, coef)).unwrap();
        }
        let decode = video::DECODE_MS * 1e-3;
        let sw = decode
            + 1e-9 * engine.profile(func).counters.cycles as f64 / 2.0;
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 8,
            pcie,
            ..Default::default()
        });
        mgr.try_offload(&mut engine, func, None).unwrap();
        for _ in 0..3 {
            src.next_frame(&mut frame);
            mem.i32s_mut(inp).copy_from_slice(&frame);
            engine.call("conv", &mut mem, &video::conv_args(out, inp, coef)).unwrap();
        }
        let st = mgr.state(func).unwrap();
        let off = decode
            + st.borrow().virtual_offload.as_secs_f64() / st.borrow().invocations as f64;
        (1.0 / sw, 1.0 / off)
    };
    let (sw, off_tagged) = fps(PcieParams::default());
    assert!(
        off_tagged < sw,
        "tagged offload must be slower (paper: 31 < 83 fps): {off_tagged:.1} vs {sw:.1}"
    );
    // Rough factor check: paper is ~2.7x; accept 1.5..6x.
    let factor = sw / off_tagged;
    assert!((1.5..6.0).contains(&factor), "slowdown factor {factor:.2}");
    let (_, off_packed) = fps(PcieParams::riffa_like());
    assert!(
        off_packed > off_tagged * 2.0,
        "packed protocol should be a big win: {off_packed:.1} vs {off_tagged:.1}"
    );
}

#[test]
fn table2_largest_routable_matches_paper() {
    // The paper's largest *square* DFEs must route, the next square must
    // not (paper reports only square grids below 15x15; the model may
    // admit slightly-rectangular shapes in between, e.g. 8x9 on S6).
    for (name, side) in [("Spartan 6", 8usize), ("Cyclone IV", 10)] {
        let d = tlo::dfe::resource::device_by_name(name).unwrap();
        assert!(d.estimate(side, side).routable, "{name} {side}x{side}");
        assert!(!d.estimate(side + 1, side + 1).routable, "{name} next square");
        let (r, c) = d.largest_routable();
        assert!(r * c >= side * side && r * c < (side + 1) * (side + 1), "{name}: {r}x{c}");
    }
    // The two big parts route 24x18 (432 cells).
    for name in ["Virtex 7", "Stratix V"] {
        let d = tlo::dfe::resource::device_by_name(name).unwrap();
        assert!(d.estimate(24, 18).routable, "{name} must route 24x18");
    }
}

#[test]
fn rollback_restores_and_results_stay_correct() {
    let mut engine = Engine::new(mixed_module()).unwrap();
    let mut mem = Memory::new();
    let n = 64; // tiny -> offload loses -> rollback
    let hy = mem.alloc_i32(n);
    let hx = mem.from_i32(&vec![1i32; n]);
    let args = [Val::P(hy), Val::P(hx), Val::I(n as i32)];
    engine.call("hot", &mut mem, &args).unwrap();
    let mut mgr = OffloadManager::new(OffloadParams {
        min_dfg_nodes: 1,
        rollback_window: 1,
        ..Default::default()
    });
    let f = engine.func_index("hot").unwrap();
    mgr.try_offload(&mut engine, f, None).unwrap();
    engine.call("hot", &mut mem, &args).unwrap();
    assert_eq!(mgr.check_rollback(&mut engine), vec![f]);
    // Post-rollback invocation is pure software and still correct.
    let before = mem.i32s(hy).to_vec();
    engine.call("hot", &mut mem, &args).unwrap();
    for i in 0..n {
        assert_eq!(mem.i32s(hy)[i], before[i] + 5);
    }
}

//! Serve-layer properties (self-contained generator harness, like
//! tests/proptests.rs — proptest is not in the offline image):
//!   S1  any partition of any grid into N shard regions is disjoint and
//!       covering — no cell is ever shared between regions;
//!   S2  any interleaving of K tenants across N shards (random mixes,
//!       shard counts, batch windows and request counts) produces outputs
//!       that match a pure-interpreter replay of the same streams;
//!   S3  the shared-key config cache hit-rate with multiple tenants is >=
//!       the single-tenant baseline (and >= 50 % for a same-kernel mix);
//!   S4  serve outputs are bit-identical to the single-tenant offload
//!       path (the acceptance contract behind `tlo serve --verify`);
//!   S6  the asynchronous transport pipeline ≡ the synchronous transport
//!       ≡ the interpreter, bit-for-bit, across ≥3 tenants with adaptive
//!       respecialization on — the transport mode re-times transfers but
//!       must never change numerics, and async must not be slower than
//!       sync on the transfer-bound tagged link;
//!   S7  with the compile service on, async + adapt serve output stays
//!       bit-identical to the synchronous-compile path, the respec trace
//!       still shows tier transitions, and no tenant ever blocks inside
//!       place & route after admission (compile_stall_secs == 0);
//!   S8  a tenant whose DFG exceeds its shard region offloads anyway as a
//!       multi-tile execution plan: outputs stay bit-identical to the
//!       interpreter under both transport modes, single-tile co-tenants
//!       are unaffected, and the async multi-pass pipeline never loses to
//!       the synchronous one on makespan;
//!   S9  SLO admission control under overload: the top priority class is
//!       never shed, lower classes shed to the software tier, latency
//!       percentiles surface per tenant, and shedding never changes
//!       numerics (outputs stay bit-identical to the single-tenant
//!       offload oracle).

use tlo::dfe::grid::Grid;
use tlo::jit::engine::Engine;
use tlo::jit::interp::Memory;
use tlo::offload::server::{
    gemm_spec, gesummv_spec, polybench_mix, run_single_tenant, syr2k_spec, trmm_spec,
    OffloadServer, ServeParams, TenantSpec, WARMUP_REQUESTS,
};
use tlo::offload::{OffloadManager, OffloadParams};
use tlo::util::prng::Rng;

/// Pure-software replay of a tenant stream: the interpreter oracle.
fn interpreter_outputs(spec: &TenantSpec, requests: u64) -> Vec<Vec<i32>> {
    let mut engine = Engine::new((spec.module)()).unwrap();
    let mut mem = Memory::new();
    let args = (spec.setup)(&mut mem);
    let func = engine.func_index(spec.func).unwrap();
    for seq in 0..WARMUP_REQUESTS + requests {
        if let Some(refresh) = spec.refresh {
            refresh(&mut mem, &args, seq);
        }
        engine.call_idx(func, &mut mem, &args).unwrap();
    }
    (spec.outputs)(&args).into_iter().map(|h| mem.i32s(h).to_vec()).collect()
}

#[test]
fn s1_random_partitions_never_share_a_cell() {
    let mut rng = Rng::new(0x5A1);
    for case in 0..200u64 {
        let rows = 1 + rng.below(20);
        let cols = 1 + rng.below(20);
        let g = Grid::new(rows, cols);
        let k = 1 + rng.below(rows.max(cols));
        let Ok(regions) = g.partition(k) else {
            assert!(k > rows.max(cols), "case {case}: partition refused a feasible k={k}");
            continue;
        };
        assert_eq!(regions.len(), k, "case {case}");
        let mut seen = std::collections::HashSet::new();
        for r in &regions {
            for cell in r.cells() {
                assert!(g.contains(cell), "case {case}: {cell} off-grid");
                assert!(seen.insert(cell), "case {case}: cell {cell} in two regions");
            }
        }
        assert_eq!(seen.len(), g.n_cells(), "case {case}: partition must cover");
        for i in 0..k {
            for j in i + 1..k {
                assert!(!regions[i].overlaps(regions[j]), "case {case}: {i}/{j} overlap");
            }
        }
    }
}

#[test]
fn s2_random_interleavings_match_the_interpreter() {
    let pool: [fn() -> TenantSpec; 4] = [gemm_spec, trmm_spec, syr2k_spec, gesummv_spec];
    let mut rng = Rng::new(0x5A2);
    for case in 0..6u64 {
        let n_tenants = 2 + rng.below(3); // 2..=4
        let shards = 1 + rng.below(4); // 1..=4
        let requests = 1 + rng.below(4) as u64; // 1..=4
        let batch_window = rng.below(2 * n_tenants + 1); // 0 = per-tenant
        let specs: Vec<TenantSpec> = (0..n_tenants)
            .map(|i| {
                let mut s = pool[rng.below(pool.len())]();
                s.name = format!("{}-c{case}t{i}", s.name);
                s
            })
            .collect();
        let params = ServeParams {
            shards,
            batch_window,
            seed: 0xC0DE + case,
            ..Default::default()
        };
        let mut server = OffloadServer::new(params, specs.clone())
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        // The disjointness invariant holds on the live server too.
        for i in 0..server.regions.len() {
            for j in i + 1..server.regions.len() {
                assert!(!server.regions[i].overlaps(server.regions[j]));
            }
        }
        server.run(requests);
        for (i, spec) in specs.iter().enumerate() {
            let want = interpreter_outputs(spec, requests);
            assert_eq!(
                server.tenant_outputs(i),
                want,
                "case {case} ({shards} shards, window {batch_window}): tenant {} diverges",
                spec.name
            );
        }
    }
}

#[test]
fn s3_shared_cache_hit_rate_at_least_single_tenant_baseline() {
    // Single-tenant baseline: one manager, one offload — all misses.
    let mut engine = Engine::new({
        let mut m = tlo::ir::func::Module::new();
        m.add(tlo::workloads::polybench::gemm());
        m
    })
    .unwrap();
    let mut mgr = OffloadManager::new(OffloadParams {
        min_dfg_nodes: 1,
        unroll: 2,
        ..Default::default()
    });
    let func = engine.func_index("gemm").unwrap();
    mgr.try_offload(&mut engine, func, None).expect("gemm offloads");
    let single_manager_rate = mgr.cache.hit_rate();

    // Single-tenant server: same shape, one tenant.
    let single_server =
        OffloadServer::new(ServeParams::default(), vec![gemm_spec()]).expect("server");
    let single_server_rate = single_server.cache.hit_rate();

    // Multi-tenant server with shared keys: 4 tenants of the same kernel.
    let specs: Vec<TenantSpec> = (0..4)
        .map(|i| {
            let mut s = gemm_spec();
            s.name = format!("gemm-{i}");
            s
        })
        .collect();
    let multi = OffloadServer::new(ServeParams::default(), specs).expect("server");
    let multi_rate = multi.cache.hit_rate();

    assert!(
        multi_rate >= single_manager_rate,
        "shared-key hit rate {multi_rate} < manager baseline {single_manager_rate}"
    );
    assert!(
        multi_rate >= single_server_rate,
        "shared-key hit rate {multi_rate} < single-tenant server {single_server_rate}"
    );
    assert!(multi_rate >= 0.5, "same-kernel mix should mostly hit, got {multi_rate}");
    // And only one place&route happened for the four tenants.
    assert_eq!(multi.cache.len(), 1);
}

#[test]
fn s4_serve_outputs_bit_identical_to_single_tenant_offload_path() {
    let requests = 5u64;
    let specs = polybench_mix(4);
    let mut server = OffloadServer::new(
        ServeParams { shards: 2, ..Default::default() },
        specs.clone(),
    )
    .expect("server");
    // The mix must actually exercise the shards for the comparison to
    // mean anything.
    let offloaded = server.tenants.iter().filter(|t| t.offload.is_some()).count();
    assert!(offloaded >= 3, "only {offloaded}/4 tenants offloaded");
    let report = server.run(requests);
    assert_eq!(report.total_requests, 4 * requests);
    for (i, spec) in specs.iter().enumerate() {
        let want = run_single_tenant(spec, requests).expect("single-tenant replay");
        assert_eq!(
            server.tenant_outputs(i),
            want,
            "tenant {} diverges from the single-tenant offload path",
            spec.name
        );
    }
}

#[test]
fn s6_async_transport_matches_sync_and_interpreter_with_adapt_on() {
    use tlo::offload::adapt::AdaptParams;
    use tlo::transport::TransportMode;

    let requests = 6u64;
    let specs = polybench_mix(4);
    let run_mode = |transport: TransportMode| {
        let params = ServeParams {
            shards: 2,
            transport,
            adapt: Some(AdaptParams {
                decision_window: 2,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut server = OffloadServer::new(params, specs.clone()).expect("server");
        let offloaded = server.tenants.iter().filter(|t| t.offload.is_some()).count();
        assert!(offloaded >= 3, "only {offloaded}/4 tenants offloaded");
        let report = server.run(requests);
        let outs: Vec<Vec<Vec<i32>>> =
            (0..server.n_tenants()).map(|i| server.tenant_outputs(i)).collect();
        (outs, report)
    };
    let (outs_sync, rep_sync) = run_mode(TransportMode::Sync);
    let (outs_async, rep_async) = run_mode(TransportMode::async_default());
    let (outs_deep, _) = run_mode(TransportMode::Async { depth: 4 });

    for (i, spec) in specs.iter().enumerate() {
        let interp = interpreter_outputs(spec, requests);
        assert_eq!(outs_sync[i], interp, "sync vs interpreter: tenant {}", spec.name);
        assert_eq!(outs_async[i], interp, "async vs interpreter: tenant {}", spec.name);
        assert_eq!(outs_deep[i], interp, "async:4 vs interpreter: tenant {}", spec.name);
    }
    // Same work either way; the pipeline may only re-time it.
    assert_eq!(rep_sync.total_requests, rep_async.total_requests);
    assert_eq!(rep_sync.total_elements, rep_async.total_elements);
    assert!(rep_async.total_elements > 0, "the mix must offload elements");
    assert!(
        rep_async.makespan <= rep_sync.makespan,
        "overlap must never lose: async {:?} vs sync {:?}",
        rep_async.makespan,
        rep_sync.makespan
    );
}

#[test]
fn s7_compile_service_serves_without_par_stalls_and_stays_bit_identical() {
    use tlo::offload::adapt::AdaptParams;

    let requests = 6u64;
    let specs = polybench_mix(4);
    let adapt = Some(AdaptParams {
        decision_window: 2,
        candidate_unrolls: vec![4],
        min_lanes: 4,
        ..Default::default()
    });

    // Synchronous-compile reference: a respecialization miss stalls the
    // serving path inside place & route (counted per tenant).
    let mut sync_server = OffloadServer::new(
        ServeParams { shards: 2, adapt: adapt.clone(), ..Default::default() },
        specs.clone(),
    )
    .expect("sync-compile server");
    let sync_report = sync_server.run(requests);

    // Compile service on: 4-seed portfolio racing on 2 background
    // threads; respecs submit jobs and keep serving the current tier.
    let mut svc_server = OffloadServer::new(
        ServeParams {
            shards: 2,
            adapt,
            portfolio: 4,
            compile_threads: 2,
            ..Default::default()
        },
        specs.clone(),
    )
    .expect("compile-service server");
    // Phase 1: decision windows fire and submit background jobs.
    svc_server.run(requests / 2);
    // Round-boundary barrier (test-only determinism; `run` itself pumps
    // non-blockingly every round): let the in-flight artifacts land...
    svc_server.drain_compiles();
    // Phase 2: ...so the next decision windows swap them in as cache hits.
    let svc_report = svc_server.run(requests - requests / 2);

    // The tentpole invariant: no tenant invocation ever blocked on P&R.
    for t in &svc_report.tenants {
        assert_eq!(
            t.compile_stall_secs, 0.0,
            "tenant {} stalled inside place & route with the service on",
            t.name
        );
    }
    assert_eq!(svc_report.compile_stall_secs, 0.0);
    assert_eq!(svc_report.pending_compiles, 0, "drained service must be empty");
    // The respec trace still shows live tier transitions — compiles were
    // hidden, not skipped.
    let svc_respecs: u64 = svc_report.tenants.iter().map(|t| t.respecializations).sum();
    assert!(svc_respecs >= 1, "the service must still deliver respecializations");
    // Output is bit-identical to the synchronous-compile path and the
    // interpreter — the service re-times compilation, never numerics.
    for (i, spec) in specs.iter().enumerate() {
        let interp = interpreter_outputs(spec, requests);
        assert_eq!(sync_server.tenant_outputs(i), interp, "sync tenant {}", spec.name);
        assert_eq!(svc_server.tenant_outputs(i), interp, "service tenant {}", spec.name);
    }
    // The invariant is not vacuous: the synchronous reference paid a real
    // stall for the same respecializations.
    let sync_respecs: u64 = sync_report.tenants.iter().map(|t| t.respecializations).sum();
    if sync_respecs > 0 {
        assert!(
            sync_report.compile_stall_secs > 0.0,
            "synchronous respecialization must stall inside P&R"
        );
    }
}

#[test]
fn s5_tagged_protocol_interleavings_also_match() {
    // The paper's tagged prototype protocol (transfer-bound, rollbacks
    // likely) must preserve numerics just the same.
    let specs = polybench_mix(3);
    let params = ServeParams {
        shards: 3,
        pcie: tlo::transport::PcieParams::default(),
        rollback_window: 2,
        ..Default::default()
    };
    let mut server = OffloadServer::new(params, specs.clone()).expect("server");
    server.run(4);
    for (i, spec) in specs.iter().enumerate() {
        assert_eq!(
            server.tenant_outputs(i),
            interpreter_outputs(spec, 4),
            "tenant {} diverges under the tagged protocol",
            spec.name
        );
    }
}

#[test]
fn s8_oversized_tenant_serves_as_a_multi_tile_plan_without_hurting_others() {
    use tlo::transport::TransportMode;

    let requests = 4u64;
    // gemm at unroll 8 carries more calc nodes than a 3x6 shard region
    // (6x6 grid, 2 shards) has cells; before tiled plans it was rejected
    // with TooLarge and pinned to the interpreter.
    let mut big = gemm_spec();
    big.name = "gemm-big".into();
    big.unroll = 8;
    let specs = vec![big, trmm_spec(), gesummv_spec()];
    let run_mode = |transport: TransportMode| {
        let params = ServeParams {
            shards: 2,
            grid: Grid::new(6, 6),
            transport,
            // A multi-pass plan pays per-tile reconfiguration on every
            // invocation, which at these toy problem sizes dwarfs the
            // interpreter baseline — park the economics rollback so the
            // correctness surface stays offloaded for the whole run.
            rollback_window: 1_000_000,
            ..Default::default()
        };
        let mut server = OffloadServer::new(params, specs.clone()).expect("server");
        let plan_tiles = server.tenants[0]
            .plan
            .as_ref()
            .map(|p| p.n_tiles())
            .expect("the oversized tenant must admit as a tiled plan");
        assert!(plan_tiles > 1, "gemm@u8 must not fit a 3x6 region in one tile");
        assert!(server.tenants[1].offload.is_some(), "trmm must still offload");
        assert!(server.tenants[1].plan.is_none(), "trmm stays single-tile");
        let report = server.run(requests);
        assert_eq!(report.tenants[0].tiles, plan_tiles, "report must surface the cut");
        assert_eq!(report.tenants[1].tiles, 1, "co-tenant report stays single-tile");
        let outs: Vec<Vec<Vec<i32>>> =
            (0..server.n_tenants()).map(|i| server.tenant_outputs(i)).collect();
        (outs, report)
    };
    let (outs_sync, rep_sync) = run_mode(TransportMode::Sync);
    let (outs_async, rep_async) = run_mode(TransportMode::async_default());
    for (i, spec) in specs.iter().enumerate() {
        let interp = interpreter_outputs(spec, requests);
        assert_eq!(outs_sync[i], interp, "sync vs interpreter: tenant {}", spec.name);
        assert_eq!(outs_async[i], interp, "async vs interpreter: tenant {}", spec.name);
    }
    assert_eq!(rep_sync.total_requests, rep_async.total_requests);
    assert!(
        rep_async.makespan <= rep_sync.makespan,
        "multi-pass overlap must never lose: async {:?} vs sync {:?}",
        rep_async.makespan,
        rep_sync.makespan
    );
}

#[test]
fn s9_slo_overload_sheds_low_classes_only_and_never_changes_numerics() {
    let requests = 4u64;
    // One high-class tenant against two low-class co-tenants, under an
    // SLO so tight that any round with more than the high tenant's own
    // fabric time is over budget — a deterministic overload.
    let mut high = gemm_spec();
    high.name = "gemm-high".into();
    high.priority = 3;
    let mut low_same = gemm_spec();
    low_same.name = "gemm-low".into();
    let mut low_other = trmm_spec();
    low_other.name = "trmm-low".into();
    let specs = vec![high, low_same, low_other];
    let params = ServeParams {
        shards: 2,
        slo: Some(1e-9),
        ..Default::default()
    };
    let mut server = OffloadServer::new(params, specs.clone()).expect("server");
    for (i, spec) in specs.iter().enumerate() {
        assert!(
            server.tenants[i].offload.is_some(),
            "tenant {} must offload for the shed test to bite",
            spec.name
        );
        // Pin hotness so the weighted window hands out exactly one slot
        // per tenant per round (weights 3/1/1): the high class is then in
        // every batch and the shed counts below are exact, independent of
        // what the profiler thinks of gemm vs trmm.
        server.tenants[i].hotness = 1.0;
    }
    let report = server.run(requests);

    // Policy: the top class keeps its fabric path; every lower-class
    // offloaded request sheds (its exec alone exceeds the 1 ns budget).
    let by_name = |n: &str| report.tenants.iter().find(|t| t.name == n).unwrap();
    let t_high = by_name("gemm-high");
    let t_low = by_name("gemm-low");
    let t_other = by_name("trmm-low");
    assert_eq!(t_high.shed, 0, "the top class must never shed");
    assert_eq!(t_high.priority, 3);
    assert_eq!(t_low.shed, requests, "every low-class request sheds: {t_low:?}");
    assert_eq!(t_other.shed, requests, "every low-class request sheds: {t_other:?}");
    assert_eq!(report.shed, 2 * requests, "aggregate shed is the per-tenant sum");
    assert_eq!(report.total_requests, 3 * requests, "shed requests still serve");

    // Observability: percentiles populated and monotone for every tenant.
    for t in &report.tenants {
        assert!(t.p50_secs > 0.0, "{}: empty latency histogram", t.name);
        assert!(t.p50_secs <= t.p95_secs && t.p95_secs <= t.p99_secs, "{t:?}");
    }
    // The shed tier is the (slower) software tier: the low tenant's
    // latency floor is its interpreter baseline, not the fabric time.
    assert!(
        t_low.p50_secs >= t_low.baseline_per_inv.as_secs_f64() / 2.0,
        "shed requests must account software latency: {t_low:?}"
    );

    // Correctness: shedding re-times requests, it never re-computes them.
    for (i, spec) in specs.iter().enumerate() {
        let want = run_single_tenant(spec, requests).expect("single-tenant replay");
        assert_eq!(
            server.tenant_outputs(i),
            want,
            "tenant {} diverges under SLO shedding",
            spec.name
        );
    }

    // Control: the same mix with no SLO sheds nothing.
    let mut free = OffloadServer::new(
        ServeParams { shards: 2, ..Default::default() },
        specs.clone(),
    )
    .expect("server");
    let free_report = free.run(requests);
    assert_eq!(free_report.shed, 0, "no SLO, no shedding");
    for (i, spec) in specs.iter().enumerate() {
        assert_eq!(
            server.tenant_outputs(i),
            free.tenant_outputs(i),
            "tenant {}: SLO shedding changed numerics",
            spec.name
        );
    }
}

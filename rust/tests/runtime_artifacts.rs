//! PJRT artifact tests: the rust DFE simulator and the AOT Pallas artifact
//! must agree bit-for-bit on random execution images — the cross-layer
//! correctness contract (L1 kernel ≡ L3 sim). Skipped gracefully when
//! `make artifacts` has not run.

use tlo::dfe::abi;
use tlo::dfe::image::{fig2_image, listing1_image, ExecImage, ImageCell};
use tlo::dfe::opcodes::{Op, ALL_OPS};
use tlo::runtime::PjrtRuntime;
use tlo::util::prng::Rng;

fn runtime_or_skip() -> Option<PjrtRuntime> {
    match PjrtRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn random_image(rng: &mut Rng, max_cells: usize) -> ExecImage {
    let n_inputs = 1 + rng.below(abi::N_INPUTS.min(8));
    let n_consts = rng.below(4);
    let consts: Vec<i32> = (0..n_consts).map(|_| rng.any_i32()).collect();
    let n_cells = 1 + rng.below(max_cells);
    let mut cells = Vec::new();
    for i in 0..n_cells {
        let limit = abi::CELL_BASE + i;
        let op = ALL_OPS[rng.below(ALL_OPS.len())];
        cells.push(ImageCell {
            op,
            src1: rng.below(limit),
            src2: rng.below(limit),
            sel: rng.below(limit),
        });
    }
    let n_out = 1 + rng.below(abi::N_OUTPUTS - 1);
    let out_sel: Vec<usize> =
        (0..n_out).map(|_| rng.below(abi::n_slots(n_cells))).collect();
    let img = ExecImage { cells, consts, n_inputs, out_sel };
    img.validate().expect("constructed valid");
    img
}

#[test]
fn manifest_lists_all_variants() {
    let Some(rt) = runtime_or_skip() else { return };
    let names: Vec<&str> = rt.manifest.variants.iter().map(|v| v.name.as_str()).collect();
    for want in ["dfe_4x4", "dfe_8x8", "dfe_12x12", "dfe_15x15", "dfe_24x18"] {
        assert!(names.contains(&want), "missing {want} in {names:?}");
    }
    assert_eq!(rt.manifest.batch, abi::BATCH);
}

#[test]
fn pjrt_matches_rust_sim_on_random_images() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.executable("dfe_8x8").expect("compile 8x8");
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..10 {
        let img = random_image(&mut rng, 64);
        let batch = abi::BATCH;
        let x: Vec<i32> = (0..img.n_inputs * batch).map(|_| rng.any_i32()).collect();
        let want = img.eval_batch(&x, batch);
        let got = exe.run_batch(&img, &x).expect("pjrt execute");
        assert_eq!(got, want, "case {case}");
    }
}

#[test]
fn pjrt_runs_fig2_and_listing1_images() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.executable("dfe_4x4").expect("compile 4x4");
    let mut rng = Rng::new(9);
    for img in [fig2_image(), listing1_image()] {
        let lanes = 777; // non-multiple of BATCH exercises chunking
        let x: Vec<i32> = (0..img.n_inputs * lanes).map(|_| rng.any_i32() % 10_000).collect();
        let want = img.eval_batch(&x, lanes);
        let got = exe.run_lanes(&img, &x, lanes).expect("pjrt run_lanes");
        assert_eq!(got, want);
    }
}

#[test]
fn executable_fitting_picks_smallest_variant() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert_eq!(rt.executable_fitting(3).unwrap().info.name, "dfe_4x4");
    assert_eq!(rt.executable_fitting(17).unwrap().info.name, "dfe_8x8");
    assert_eq!(rt.executable_fitting(200).unwrap().info.name, "dfe_15x15");
    assert_eq!(rt.executable_fitting(300).unwrap().info.name, "dfe_24x18");
    assert!(rt.executable_fitting(10_000).is_err());
}

#[test]
fn oversized_image_rejected() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.executable("dfe_4x4").unwrap();
    let mut rng = Rng::new(1);
    let img = random_image(&mut rng, 64);
    if img.n_cells() > 16 {
        let x = vec![0i32; img.n_inputs * abi::BATCH];
        assert!(exe.run_batch(&img, &x).is_err());
    }
}

//! E8 + A2 — Las-Vegas place & route behaviour:
//!   * runtime distribution over seeds for the §IV-C conv DFG (the paper
//!     observes "a random time ... in this example 1.18 s");
//!   * scaling over DFG size and grid size;
//!   * heat-3d's merged ~300-node DFG failing on 24x18 (Table I note);
//!   * configuration-cache hit vs cold P&R (A2).

use tlo::analysis::scop::analyze_function;
use tlo::dfe::cache::{dfg_key, CachedConfig, ConfigCache};
use tlo::dfe::grid::Grid;
use tlo::dfg::extract::extract;
use tlo::par::{place_and_route, ParParams};
use tlo::util::bench::{black_box, print_header, run, BenchConfig};
use tlo::util::prng::Rng;
use tlo::util::{fmt_duration, mean_std, median};
use tlo::workloads::video::conv_func;

fn main() {
    let cfg = BenchConfig::from_env();
    let params = ParParams::default();

    // --- runtime distribution for the conv DFG (17/1/16) ---
    let f = conv_func();
    let an = analyze_function(&f);
    let off = extract(&f, &an.scops[0], 1).unwrap();
    println!("== E8: Las-Vegas P&R runtime distribution (conv 17/1/16 DFG) ==");
    for grid in [Grid::new(8, 8), Grid::new(12, 12), Grid::new(24, 18)] {
        let mut times = Vec::new();
        let mut restarts = 0u64;
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let r = place_and_route(&off.dfg, grid, &params, &mut rng).expect("routable");
            times.push(r.stats.elapsed.as_secs_f64());
            restarts += r.stats.restarts;
        }
        let (m, s) = mean_std(&times);
        println!(
            "  {}x{}: median {} mean {} std {} (20 seeds, {} total restarts)",
            grid.rows,
            grid.cols,
            fmt_duration(std::time::Duration::from_secs_f64(median(&times))),
            fmt_duration(std::time::Duration::from_secs_f64(m)),
            fmt_duration(std::time::Duration::from_secs_f64(s)),
            restarts
        );
    }

    // --- heat-3d: the paper's P&R failure on the largest DFE ---
    let h = tlo::workloads::polybench::heat3d();
    let han = analyze_function(&h);
    let mut merged = extract(&h, &han.scops[0], 4).unwrap().dfg;
    // Merge the second nest to approximate the paper's combined DFG,
    // re-indexing its external streams past the first nest's.
    let second = extract(&h, &han.scops[1], 4).unwrap().dfg;
    let offset = merged.len();
    let in_off = merged.stats().inputs;
    let out_off = merged.stats().outputs;
    for node in &second.nodes {
        let srcs = node.srcs.iter().map(|s| s + offset).collect();
        let kind = match &node.kind {
            tlo::dfg::graph::NodeKind::Input(j) => tlo::dfg::graph::NodeKind::Input(j + in_off),
            tlo::dfg::graph::NodeKind::Output(j) => {
                tlo::dfg::graph::NodeKind::Output(j + out_off)
            }
            k => k.clone(),
        };
        merged.nodes.push(tlo::dfg::graph::Node { kind, srcs });
    }
    let calc = merged.stats().calc;
    let mut rng = Rng::new(1);
    let quick = ParParams { max_restarts: 4, ..params };
    let res = place_and_route(&merged, Grid::new(24, 18), &quick, &mut rng);
    println!(
        "\nheat-3d merged DFG ({calc} calc nodes) on 24x18: {} (paper: fails to map)",
        match res {
            Ok(_) => "ROUTED (model diverges)".to_string(),
            Err(e) => format!("fails — {e}"),
        }
    );

    // --- A2: cache hit vs cold ---
    print_header("A2 — configuration cache");
    run("par/cold (conv on 24x18)", cfg, || {
        let mut rng = Rng::new(7);
        black_box(place_and_route(&off.dfg, Grid::new(24, 18), &params, &mut rng).unwrap());
    });
    let mut cache = ConfigCache::new(8);
    let mut rng = Rng::new(7);
    let r = place_and_route(&off.dfg, Grid::new(24, 18), &params, &mut rng).unwrap();
    cache.insert(
        dfg_key(&off.dfg),
        CachedConfig::new(r.config, r.image, "dfe_24x18".into()),
    );
    run("par/cache-hit", cfg, || {
        black_box(cache.get(dfg_key(&off.dfg)).is_some());
    });
    println!("cache stats: {:?}", cache.stats);
}

//! E8 + A2 + A8 — Las-Vegas place & route behaviour and the compile
//! service ablation:
//!   * E8: runtime distribution over seeds for the §IV-C conv DFG (the
//!     paper observes "a random time ... in this example 1.18 s");
//!   * heat-3d's merged ~300-node DFG failing on 24x18 (Table I note);
//!   * A2: configuration-cache hit vs cold P&R;
//!   * A8: racing seed-portfolio (K) vs single-seed latency
//!     distributions (p50/p95) on the PolyBench mix, and warm-started
//!     tier N→N+1 respecialization vs cold compile.
//!
//! With `TLO_BENCH_JSON=<path>` (set by `make bench`) the A8 numbers are
//! written to `BENCH_par.json` — the only committed perf-trajectory
//! record for the compile path.

use std::time::Instant;

use tlo::analysis::scop::analyze_function;
use tlo::dfe::cache::{dfg_key, CachedConfig, ConfigCache};
use tlo::dfe::grid::Grid;
use tlo::dfg::extract::extract;
use tlo::dfg::graph::Dfg;
use tlo::par::{
    derive_seed, place_and_route, place_and_route_portfolio, place_and_route_seeded,
    ParParams, ParSeed, PortfolioParams,
};
use tlo::util::bench::{black_box, print_header, run, BenchConfig};
use tlo::util::json::escape;
use tlo::util::prng::Rng;
use tlo::util::{fmt_duration, mean_std, median};
use tlo::workloads::polybench;
use tlo::workloads::video::conv_func;

const PORTFOLIO_K: usize = 4;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn dfg_of(f: &tlo::ir::func::Function, unroll: usize) -> Dfg {
    let an = analyze_function(f);
    extract(f, &an.scops[0], unroll).expect("extracts").dfg
}

/// One workload's single-seed vs portfolio-K latency distributions. An
/// unroutable draw is charged its full failure time (that is what the
/// caller pays before falling back) — the portfolio rescues such draws
/// whenever any seed routes.
struct DistRow {
    name: String,
    single_p50: f64,
    single_p95: f64,
    portfolio_p50: f64,
    portfolio_p95: f64,
}

fn distribution(
    name: &str,
    dfg: &Dfg,
    grid: Grid,
    params: &ParParams,
    samples: usize,
) -> DistRow {
    let mut single = Vec::with_capacity(samples);
    for s in 0..samples as u64 {
        let mut rng = Rng::new(derive_seed(0xE8, s as usize));
        let t0 = Instant::now();
        let _ = black_box(place_and_route(dfg, grid, params, &mut rng));
        single.push(t0.elapsed().as_secs_f64());
    }
    let mut portfolio = Vec::with_capacity(samples);
    for base in 0..samples as u64 {
        let pf = PortfolioParams {
            k: PORTFOLIO_K,
            base_seed: 0xA8_0000 + base,
            threads: PORTFOLIO_K,
        };
        let t0 = Instant::now();
        let _ = black_box(place_and_route_portfolio(dfg, grid, params, &ParSeed::Cold, &pf));
        portfolio.push(t0.elapsed().as_secs_f64());
    }
    single.sort_by(f64::total_cmp);
    portfolio.sort_by(f64::total_cmp);
    DistRow {
        name: name.to_string(),
        single_p50: percentile(&single, 0.5),
        single_p95: percentile(&single, 0.95),
        portfolio_p50: percentile(&portfolio, 0.5),
        portfolio_p95: percentile(&portfolio, 0.95),
    }
}

/// One warm-vs-cold tier transition: route tier N cold, then time tier
/// N+1 cold vs warm-started from N's placement (same derived seed, so
/// the only difference is the hint).
struct WarmRow {
    name: String,
    cold_secs: f64,
    warm_secs: f64,
}

fn warm_transition(
    name: &str,
    f: &tlo::ir::func::Function,
    from_u: usize,
    to_u: usize,
    grid: Grid,
    params: &ParParams,
    seed: u64,
) -> Option<WarmRow> {
    let prior = {
        let mut rng = Rng::new(derive_seed(seed, 0));
        place_and_route(&dfg_of(f, from_u), grid, params, &mut rng).ok()?
    };
    let next = dfg_of(f, to_u);
    let t0 = Instant::now();
    let cold = {
        let mut rng = Rng::new(derive_seed(seed, 1));
        place_and_route_seeded(&next, grid, params, &mut rng, &ParSeed::Cold, None)
    };
    let cold_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let warm = {
        let mut rng = Rng::new(derive_seed(seed, 1));
        place_and_route_seeded(
            &next,
            grid,
            params,
            &mut rng,
            &ParSeed::Warm(prior.placement.clone()),
            None,
        )
    };
    let warm_secs = t1.elapsed().as_secs_f64();
    if cold.is_err() || warm.is_err() {
        return None;
    }
    Some(WarmRow { name: format!("{name} u{from_u}->u{to_u}"), cold_secs, warm_secs })
}

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("TLO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let params = ParParams::default();

    // --- E8: runtime distribution for the conv DFG (17/1/16) ---
    let f = conv_func();
    let off = dfg_of(&f, 1);
    println!("== E8: Las-Vegas P&R runtime distribution (conv 17/1/16 DFG) ==");
    for grid in [Grid::new(8, 8), Grid::new(12, 12), Grid::new(24, 18)] {
        let mut times = Vec::new();
        let mut restarts = 0u64;
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let r = place_and_route(&off, grid, &params, &mut rng).expect("routable");
            times.push(r.stats.elapsed.as_secs_f64());
            restarts += r.stats.restarts;
        }
        let (m, s) = mean_std(&times);
        println!(
            "  {}x{}: median {} mean {} std {} (20 seeds, {} total restarts)",
            grid.rows,
            grid.cols,
            fmt_duration(std::time::Duration::from_secs_f64(median(&times))),
            fmt_duration(std::time::Duration::from_secs_f64(m)),
            fmt_duration(std::time::Duration::from_secs_f64(s)),
            restarts
        );
    }

    // --- heat-3d: the paper's P&R failure on the largest DFE ---
    let h = polybench::heat3d();
    let han = analyze_function(&h);
    let mut merged = extract(&h, &han.scops[0], 4).unwrap().dfg;
    // Merge the second nest to approximate the paper's combined DFG,
    // re-indexing its external streams past the first nest's.
    let second = extract(&h, &han.scops[1], 4).unwrap().dfg;
    let offset = merged.len();
    let in_off = merged.stats().inputs;
    let out_off = merged.stats().outputs;
    for node in &second.nodes {
        let srcs = node.srcs.iter().map(|s| s + offset).collect();
        let kind = match &node.kind {
            tlo::dfg::graph::NodeKind::Input(j) => tlo::dfg::graph::NodeKind::Input(j + in_off),
            tlo::dfg::graph::NodeKind::Output(j) => {
                tlo::dfg::graph::NodeKind::Output(j + out_off)
            }
            k => k.clone(),
        };
        merged.nodes.push(tlo::dfg::graph::Node { kind, srcs });
    }
    let calc = merged.stats().calc;
    let mut rng = Rng::new(1);
    let quick_params = ParParams { max_restarts: 4, ..params };
    let res = place_and_route(&merged, Grid::new(24, 18), &quick_params, &mut rng);
    println!(
        "\nheat-3d merged DFG ({calc} calc nodes) on 24x18: {} (paper: fails to map)",
        match res {
            Ok(_) => "ROUTED (model diverges)".to_string(),
            Err(e) => format!("fails — {e}"),
        }
    );

    // --- A2: cache hit vs cold ---
    print_header("A2 — configuration cache");
    run("par/cold (conv on 24x18)", cfg, || {
        let mut rng = Rng::new(7);
        black_box(place_and_route(&off, Grid::new(24, 18), &params, &mut rng).unwrap());
    });
    let mut cache = ConfigCache::new(8);
    let mut rng = Rng::new(7);
    let r = place_and_route(&off, Grid::new(24, 18), &params, &mut rng).unwrap();
    cache.insert(
        dfg_key(&off),
        CachedConfig::with_provenance(
            r.config,
            r.image,
            "dfe_24x18".into(),
            7,
            r.stats,
            r.placement,
        ),
    );
    run("par/cache-hit", cfg, || {
        black_box(cache.get(dfg_key(&off)).is_some());
    });
    println!("cache stats: {:?}", cache.stats);

    // --- A8a: racing seed portfolio vs single seed ---
    // Tight fits restart often, so the single-seed distribution is
    // heavy-tailed; racing K seeds takes (roughly) the min of K draws
    // and collapses the tail. The PolyBench mix at serve-like unrolls
    // plus conv, on the serve route-grid shapes.
    let samples = if quick { 6 } else { 24 };
    print_header(&format!(
        "A8 — single-seed vs portfolio-K race (K={PORTFOLIO_K}, {samples} draws)"
    ));
    let gemm = polybench::gemm();
    let trmm = polybench::trmm();
    let syr2k = polybench::syr2k();
    let mix: Vec<(String, Dfg, Grid)> = vec![
        ("conv@8x8".into(), dfg_of(&f, 1), Grid::new(8, 8)),
        ("conv@12x12".into(), dfg_of(&f, 1), Grid::new(12, 12)),
        ("gemm-u8@8x8".into(), dfg_of(&gemm, 8), Grid::new(8, 8)),
        ("trmm-u8@8x8".into(), dfg_of(&trmm, 8), Grid::new(8, 8)),
        ("syr2k-u8@8x8".into(), dfg_of(&syr2k, 8), Grid::new(8, 8)),
    ];
    let mut rows = Vec::new();
    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "workload", "1-seed p50", "1-seed p95", "race p50", "race p95", "p95 spd"
    );
    for (name, dfg, grid) in &mix {
        let row = distribution(name, dfg, *grid, &params, samples);
        println!(
            "{:<16} {:>12} {:>12} {:>12} {:>12} {:>7.2}x",
            row.name,
            fmt_duration(std::time::Duration::from_secs_f64(row.single_p50)),
            fmt_duration(std::time::Duration::from_secs_f64(row.single_p95)),
            fmt_duration(std::time::Duration::from_secs_f64(row.portfolio_p50)),
            fmt_duration(std::time::Duration::from_secs_f64(row.portfolio_p95)),
            row.single_p95 / row.portfolio_p95.max(1e-12)
        );
        rows.push(row);
    }
    // Aggregate p95 speedup: geometric mean across the mix.
    let p95_speedup = (rows
        .iter()
        .map(|r| (r.single_p95 / r.portfolio_p95.max(1e-12)).ln())
        .sum::<f64>()
        / rows.len() as f64)
        .exp();
    let p95_threshold = if quick { 0.8 } else { 2.0 };
    println!(
        "aggregate p95 speedup: {p95_speedup:.2}x (threshold {p95_threshold}x, {} mode)",
        if quick { "smoke" } else { "full" }
    );
    assert!(
        p95_speedup >= p95_threshold,
        "portfolio race p95 speedup {p95_speedup:.2}x below {p95_threshold}x"
    );

    // --- A8b: warm-started respecialization vs cold compile ---
    print_header("A8 — warm-started tier N->N+1 vs cold compile");
    let grid = Grid::new(12, 12);
    let tier_seeds: u64 = if quick { 2 } else { 4 };
    let kernels: Vec<(&str, tlo::ir::func::Function)> = vec![
        ("gemm", polybench::gemm()),
        ("trmm", polybench::trmm()),
        ("syr2k", polybench::syr2k()),
        ("gesummv", polybench::gesummv()),
    ];
    let mut warm_rows: Vec<WarmRow> = Vec::new();
    for (name, func) in &kernels {
        for (from_u, to_u) in [(2usize, 4usize), (4, 8)] {
            for s in 0..tier_seeds {
                if let Some(row) =
                    warm_transition(name, func, from_u, to_u, grid, &params, 0xA8B0 + s)
                {
                    warm_rows.push(row);
                }
            }
        }
    }
    let wins = warm_rows.iter().filter(|r| r.warm_secs < r.cold_secs).count();
    let win_rate = wins as f64 / warm_rows.len().max(1) as f64;
    let mean_speedup = (warm_rows
        .iter()
        .map(|r| (r.cold_secs / r.warm_secs.max(1e-12)).ln())
        .sum::<f64>()
        / warm_rows.len().max(1) as f64)
        .exp();
    println!(
        "{} transitions, warm wins {} ({:.0}%), mean speedup {:.2}x",
        warm_rows.len(),
        wins,
        100.0 * win_rate,
        mean_speedup
    );
    let warm_threshold = if quick { 0.4 } else { 0.8 };
    assert!(
        win_rate >= warm_threshold,
        "warm-start win rate {win_rate:.2} below {warm_threshold}"
    );

    // ---- perf-trajectory JSON (written by `make bench`) ----
    if let Ok(path) = std::env::var("TLO_BENCH_JSON") {
        let mut workloads = String::new();
        for (i, r) in rows.iter().enumerate() {
            if i > 0 {
                workloads.push(',');
            }
            workloads.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"single_p50_sec\": {:.6}, \"single_p95_sec\": {:.6}, \
                 \"portfolio_p50_sec\": {:.6}, \"portfolio_p95_sec\": {:.6}, \
                 \"p95_speedup\": {:.3}}}",
                escape(&r.name),
                r.single_p50,
                r.single_p95,
                r.portfolio_p50,
                r.portfolio_p95,
                r.single_p95 / r.portfolio_p95.max(1e-12)
            ));
        }
        let mut transitions = String::new();
        for (i, r) in warm_rows.iter().enumerate() {
            if i > 0 {
                transitions.push(',');
            }
            transitions.push_str(&format!(
                "\n      {{\"name\": \"{}\", \"cold_sec\": {:.6}, \"warm_sec\": {:.6}, \
                 \"speedup\": {:.3}}}",
                escape(&r.name),
                r.cold_secs,
                r.warm_secs,
                r.cold_secs / r.warm_secs.max(1e-12)
            ));
        }
        let doc = format!(
            "{{\n  \"bench\": \"par\",\n  \"mode\": \"{}\",\n  \"portfolio_k\": {},\n  \
             \"samples\": {},\n  \"workloads\": [{}\n  ],\n  \
             \"aggregate_p95_speedup\": {:.3},\n  \"warm_start\": {{\n    \
             \"transitions\": {},\n    \"warm_wins\": {},\n    \"win_rate\": {:.3},\n    \
             \"mean_speedup\": {:.3},\n    \"per_transition\": [{}\n    ]\n  }},\n  \
             \"thresholds\": {{\"p95_speedup\": {}, \"warm_win_rate\": {}}}\n}}\n",
            if quick { "quick" } else { "full" },
            PORTFOLIO_K,
            samples,
            workloads,
            p95_speedup,
            warm_rows.len(),
            wins,
            win_rate,
            mean_speedup,
            p95_threshold,
            warm_threshold
        );
        std::fs::write(&path, doc).expect("write TLO_BENCH_JSON");
        println!("wrote {path}");
    }
}

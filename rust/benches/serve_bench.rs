//! A4 — serve-layer throughput scaling: the PolyBench mix served by the
//! multi-tenant offload server at 1, 2 and 4 shard regions of the same
//! 12x12 overlay — plus A7, the transport ablation: the same mix under
//! the synchronous (blocking) link discipline vs the overlapped
//! double-buffered pipeline.
//!
//! What scales: with one shard, four structurally distinct kernels thrash
//! the single resident configuration (every round pays reconfiguration
//! downloads + the configuration-FSM epsilon); with four shards each
//! configuration stays resident and requests only pay the shared-link
//! transfers, which the round scheduler coalesces per shard. Rollback is
//! disabled (window = u64::MAX) so the bench isolates shard scaling from
//! the offload-vs-software economics (rollback_bench covers those).
//!
//! What overlaps (A7): on the transfer-bound tagged link the synchronous
//! server spends `upload + execute + download` per round with a full
//! barrier between rounds; the async pipeline runs the two link
//! directions concurrently and carries shard/link timelines across
//! rounds, so the element throughput approaches the `max(transfer,
//! compute)` bound. Outputs are bit-identical by construction
//! (`tests/serve.rs` S6); this bench asserts the speedup.
//!
//! What tiles (A9): a tenant whose DFG exceeds its shard region — pinned
//! to the interpreter before tiled execution plans — now serves as a
//! multi-pass plan on a 6x6 overlay. The bench asserts it genuinely left
//! the interpreter (report shows > 1 tiles) and that the co-tenant mix's
//! throughput degrades boundedly (>= 0.15x the no-oversized baseline)
//! rather than collapsing under the plan's per-tile reconfigurations.
//!
//! What degrades (A10): the same mix served by a 4-node network fleet
//! under three fault regimes — healthy links, 5% datagram loss, and one
//! node crashed for the whole run. Loss costs retries and virtual time;
//! a dead node costs placements (its breaker opens and the scheduler
//! routes around it); neither costs elements — all three regimes serve
//! identical work, and faults only move the makespan.
//!
//! What holds under overload (A11): the same mix with one tenant promoted
//! to a latency-critical SLO class, run against a fabric-time budget no
//! round can meet. The scheduler sheds best-effort classes to the
//! software tier — never the critical class, and never numerics
//! (`tests/serve.rs` S9 holds the bit-identity oracle) — and every
//! tenant's log2-bucketed latency histogram lands p50/p95/p99 in the
//! JSON. The restart leg snapshots the configuration cache to disk and
//! proves a reloaded server serves the same work with zero
//! place-&-route invocations.
//!
//! Acceptance: aggregate throughput must scale > 1.5x from 1 shard to 4,
//! and the async transport must serve >= 1.3x the sync element
//! throughput on the PolyBench mix (>= 1.05x in the quick smoke mode,
//! where tiny request counts leave little to overlap).
//!
//! With `TLO_BENCH_JSON=<path>` (set by `make bench`), writes both
//! sections as JSON so the perf trajectory is tracked across PRs.

use tlo::dfe::grid::Grid;
use tlo::offload::fleet::{FleetParams, FleetReport, FleetServer};
use tlo::offload::server::{
    gemm_spec, polybench_mix, OffloadServer, ServeParams, ServeReport, TenantSpec,
};
use tlo::transport::{FaultProfile, NetParams, PcieParams, TransportMode};
use tlo::util::fmt_duration;

fn run_mix(
    shards: usize,
    tenants: usize,
    requests: u64,
    transport: TransportMode,
    pcie: PcieParams,
) -> ServeReport {
    // 16x12 keeps even the 4-way split at 4x12 = 48 cells per region,
    // comfortable for every mix DFG's place & route.
    let params = ServeParams {
        shards,
        grid: Grid::new(16, 12),
        rollback_window: u64::MAX,
        transport,
        pcie,
        ..Default::default()
    };
    let mut server = OffloadServer::new(params, polybench_mix(tenants)).expect("server setup");
    let offloaded = server.tenants.iter().filter(|t| t.offload.is_some()).count();
    assert!(
        offloaded >= 3,
        "{shards} shards: only {offloaded}/{tenants} tenants offloaded — the \
         measurement would be meaningless"
    );
    server.run(requests)
}

fn main() {
    let quick = std::env::var("TLO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let tenants = 4;
    let requests: u64 = if quick { 8 } else { 32 };

    println!("== A4: serve throughput vs shard count (PolyBench mix, {tenants} tenants x {requests} requests) ==");
    println!(
        "{:>7} {:>14} {:>12} {:>11} {:>10} {:>10}",
        "shards", "throughput", "makespan", "reconfigs", "execs", "cache"
    );

    let mut results: Vec<(usize, f64)> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    for shards in [1usize, 2, 4] {
        let report = run_mix(
            shards,
            tenants,
            requests,
            TransportMode::Sync,
            PcieParams::riffa_like(),
        );
        let reconfigs: u64 = report.shards.iter().map(|s| s.reconfigs).sum();
        let execs: u64 = report.shards.iter().map(|s| s.executed).sum();
        println!(
            "{:>7} {:>10.1} r/s {:>12} {:>11} {:>10} {:>9.0}%",
            shards,
            report.throughput_rps(),
            fmt_duration(report.makespan),
            reconfigs,
            execs,
            100.0 * report.cache_hit_rate
        );
        results.push((shards, report.throughput_rps()));
        json_rows.push(format!(
            "\n    {{\"shards\": {}, \"requests_per_sec\": {:.2}, \
             \"makespan_sec\": {:.6}, \"reconfigs\": {}, \"execs\": {}, \
             \"cache_hit_rate\": {:.3}}}",
            shards,
            report.throughput_rps(),
            report.makespan.as_secs_f64(),
            reconfigs,
            execs,
            report.cache_hit_rate
        ));
    }

    let (_, rps1) = results[0];
    let (_, rps4) = results[2];
    let scaling = rps4 / rps1;
    println!("\nscaling 1 -> 4 shards: {scaling:.2}x (acceptance target: > 1.5x)");
    assert!(
        scaling > 1.5,
        "shard scaling {scaling:.2}x below the 1.5x acceptance threshold"
    );
    println!("PASS: multi-shard serving scales aggregate throughput {scaling:.2}x");

    // ---- A7: sync vs async transport on the transfer-bound tagged link ----
    println!(
        "\n== A7: transport ablation (2 shards, tagged protocol, {tenants} tenants x {requests} requests) =="
    );
    let sync = run_mix(2, tenants, requests, TransportMode::Sync, PcieParams::default());
    let pipe = run_mix(
        2,
        tenants,
        requests,
        TransportMode::async_default(),
        PcieParams::default(),
    );
    assert_eq!(
        sync.total_elements, pipe.total_elements,
        "the ablation must serve identical work"
    );
    let sync_eps = sync.elements_per_sec();
    let async_eps = pipe.elements_per_sec();
    let speedup = async_eps / sync_eps;
    println!(
        "{:>10} {:>16} {:>12}",
        "transport", "elements/s", "makespan"
    );
    println!("{:>10} {:>16.0} {:>12}", "sync", sync_eps, fmt_duration(sync.makespan));
    println!("{:>10} {:>16.0} {:>12}", "async", async_eps, fmt_duration(pipe.makespan));
    let threshold = if quick { 1.05 } else { 1.3 };
    println!(
        "\nasync vs sync element throughput: {speedup:.2}x (acceptance target: >= {threshold}x)"
    );
    assert!(
        speedup >= threshold,
        "async transport speedup {speedup:.2}x below the {threshold}x acceptance threshold"
    );
    println!("PASS: overlapped transport serves {speedup:.2}x the sync element throughput");

    // ---- A9: an oversized tenant on a tiled plan vs the co-tenant mix ----
    // gemm at unroll 8 does not fit a 3x6 shard region of a 6x6 overlay;
    // before tiled plans it was rejected (TooLarge) and pinned to the
    // interpreter — contributing nothing to the fabric makespan. Now it
    // serves as a multi-pass plan, so the co-tenants pay for sharing the
    // link and rounds with its per-tile reconfigurations. The bench
    // asserts the tenant really left the interpreter and that co-tenant
    // throughput is bounded-degraded, not collapsed (the floor is lenient
    // by design: multi-pass reconfiguration is genuinely expensive at
    // these toy batch sizes, and rollback economics — disabled here —
    // would otherwise arbitrate).
    println!(
        "\n== A9: oversized tenant served as a tiled plan (6x6 overlay, 2 shards, {requests} requests) =="
    );
    let small = Grid::new(6, 6);
    let others = polybench_mix(3);
    let run_small = |specs: Vec<TenantSpec>| {
        let params = ServeParams {
            shards: 2,
            grid: small,
            rollback_window: u64::MAX,
            transport: TransportMode::async_default(),
            pcie: PcieParams::default(),
            ..Default::default()
        };
        let mut server = OffloadServer::new(params, specs).expect("server setup");
        server.run(requests)
    };
    let baseline = run_small(others.clone());
    let mut big = gemm_spec();
    big.name = "gemm-big".into();
    big.unroll = 8;
    let mut specs = others.clone();
    specs.push(big);
    let with_big = run_small(specs);
    let big_row = with_big
        .tenants
        .iter()
        .find(|t| t.name == "gemm-big")
        .expect("the oversized tenant is in the report");
    assert!(
        big_row.tiles > 1,
        "gemm@u8 must leave the interpreter as a multi-tile plan, got {} tiles",
        big_row.tiles
    );
    // Same co-tenant work either way; only the shared fabric got busier.
    let cotenant_ratio =
        baseline.makespan.as_secs_f64() / with_big.makespan.as_secs_f64().max(1e-12);
    println!(
        "  oversized tenant: {} tiles; co-tenant mix makespan {} -> {} \
         (throughput ratio {cotenant_ratio:.2}x)",
        big_row.tiles,
        fmt_duration(baseline.makespan),
        fmt_duration(with_big.makespan),
    );
    let floor = 0.15;
    assert!(
        cotenant_ratio >= floor,
        "co-tenant throughput collapsed to {cotenant_ratio:.2}x (< {floor}x) when the \
         oversized tenant joined"
    );
    println!(
        "PASS: oversized tenant offloads as {} tiles; co-tenant throughput held at \
         {cotenant_ratio:.2}x (floor {floor}x)",
        big_row.tiles
    );

    // ---- A10: fleet fault ablation (healthy vs 5% loss vs a dead node) ----
    // Same mix, same seeds, three fault regimes on a 4-node fleet. Loss
    // and crashes are allowed to cost retries, placements and virtual
    // time — never elements: all three regimes must serve identical work.
    println!(
        "\n== A10: fleet fault ablation (4 nodes, {tenants} tenants x {requests} requests) =="
    );
    let run_fault = |fault: FaultProfile, node_faults: Vec<FaultProfile>| -> FleetReport {
        let serve = ServeParams {
            shards: 2,
            rollback_window: u64::MAX,
            ..Default::default()
        };
        let fleet = FleetParams {
            nodes: 4,
            net: NetParams { fault, ..NetParams::lan_like() },
            node_faults,
            fault_seed: 0xAB1E,
            ..Default::default()
        };
        let mut server =
            FleetServer::new(serve, fleet, polybench_mix(tenants)).expect("fleet setup");
        server.run(requests)
    };
    let fleet_healthy = run_fault(FaultProfile::healthy(), Vec::new());
    let fleet_lossy =
        run_fault(FaultProfile { drop: 0.05, ..FaultProfile::healthy() }, Vec::new());
    let one_dead = vec![
        FaultProfile { crash: 1.0, ..FaultProfile::healthy() },
        FaultProfile::healthy(),
        FaultProfile::healthy(),
        FaultProfile::healthy(),
    ];
    let fleet_crash = run_fault(FaultProfile::healthy(), one_dead);
    println!(
        "{:>10} {:>12} {:>9} {:>10} {:>12} {:>10}",
        "regime", "makespan", "retries", "degraded", "node0 srv", "deferred"
    );
    for (label, rep) in [
        ("healthy", &fleet_healthy),
        ("drop=5%", &fleet_lossy),
        ("1 dead", &fleet_crash),
    ] {
        println!(
            "{:>10} {:>12} {:>9} {:>10} {:>12} {:>10}",
            label,
            fmt_duration(rep.serve.makespan),
            rep.counters.retries,
            rep.counters.fallback_local,
            rep.nodes[0].served,
            rep.counters.deferred
        );
        assert_eq!(
            rep.counters.applied_results + rep.counters.fallback_local,
            rep.counters.remote_requests,
            "{label}: every remote request must apply once or degrade once"
        );
    }
    assert_eq!(
        fleet_healthy.serve.total_elements, fleet_lossy.serve.total_elements,
        "loss may never cost elements"
    );
    assert_eq!(
        fleet_healthy.serve.total_elements, fleet_crash.serve.total_elements,
        "a dead node may never cost elements"
    );
    assert_eq!(fleet_healthy.counters.retries, 0, "healthy fleet must not retry");
    assert!(
        fleet_lossy.serve.makespan >= fleet_healthy.serve.makespan,
        "loss can only add virtual time"
    );
    assert_eq!(
        fleet_crash.nodes[0].served, 0,
        "a node that is always down must serve nothing"
    );
    assert!(
        fleet_crash.nodes[0].breaker_opens >= 1,
        "the dead node's breaker must trip"
    );
    let crash_rest: u64 = fleet_crash.nodes[1..].iter().map(|n| n.served).sum();
    assert!(
        crash_rest > 0,
        "the surviving nodes must absorb the dead node's load"
    );
    println!(
        "PASS: identical elements across regimes; dead node served 0 \
         (breaker opened {}x), survivors served {crash_rest}",
        fleet_crash.nodes[0].breaker_opens
    );

    // ---- A11: SLO classes under overload + warm-restart persistence ----
    println!(
        "\n== A11: SLO shedding + warm restart (2 shards, {tenants} tenants x {requests} requests) =="
    );
    let mix_with_classes = || {
        let mut specs = polybench_mix(tenants);
        specs[0].priority = 3; // latency-critical; the rest stay best-effort (1)
        specs
    };
    let run_slo = |slo: Option<f64>, cache_dir: Option<std::path::PathBuf>| {
        let params = ServeParams {
            shards: 2,
            grid: Grid::new(16, 12),
            rollback_window: u64::MAX,
            slo,
            cache_dir,
            ..Default::default()
        };
        let mut server = OffloadServer::new(params, mix_with_classes()).expect("server setup");
        let report = server.run(requests);
        (server, report)
    };
    let (_, no_slo) = run_slo(None, None);
    // A budget far below any round's fabric time: a hard, total overload.
    let budget = 1e-9;
    let (_, with_slo) = run_slo(Some(budget), None);
    assert_eq!(no_slo.shed, 0, "no SLO budget must mean no shedding");
    assert!(with_slo.shed > 0, "an overloaded budget must shed best-effort work");
    println!(
        "{:>10} {:>6} {:>6} {:>12} {:>12} {:>12}",
        "tenant", "class", "shed", "p50", "p95", "p99"
    );
    for t in &with_slo.tenants {
        println!(
            "{:>10} {:>6} {:>6} {:>12} {:>12} {:>12}",
            t.name,
            t.priority,
            t.shed,
            fmt_duration(std::time::Duration::from_secs_f64(t.p50_secs)),
            fmt_duration(std::time::Duration::from_secs_f64(t.p95_secs)),
            fmt_duration(std::time::Duration::from_secs_f64(t.p99_secs)),
        );
        assert!(
            t.p50_secs <= t.p95_secs && t.p95_secs <= t.p99_secs,
            "{}: percentiles must be monotone",
            t.name
        );
        if t.requests > 0 {
            assert!(t.p99_secs > 0.0, "{}: served tenants must report a tail", t.name);
        }
    }
    let critical =
        with_slo.tenants.iter().find(|t| t.priority == 3).expect("critical-class row");
    assert_eq!(critical.shed, 0, "the top class must never shed");
    println!(
        "PASS: overload shed {} best-effort request(s); critical class '{}' shed 0",
        with_slo.shed, critical.name
    );

    let cache_dir = std::env::temp_dir().join(format!("tlo-bench-a11-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let (cold_server, cold) = run_slo(None, Some(cache_dir.clone()));
    tlo::dfe::persist::save_cache(&cold_server.cache, &cache_dir).expect("cache snapshot");
    let (_, warm) = run_slo(None, Some(cache_dir.clone()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    assert!(cold.pr_compiles > 0, "the cold run must place & route its working set");
    assert_eq!(warm.pr_compiles, 0, "a warm restart must serve with zero recompiles");
    assert_eq!(
        cold.total_elements, warm.total_elements,
        "a restart must serve identical work"
    );
    println!(
        "PASS: warm restart reloaded {} config(s): {} P&R invocation(s) cold -> 0 warm",
        cold_server.cache.len(),
        cold.pr_compiles
    );
    let tenant_latency: Vec<String> = with_slo
        .tenants
        .iter()
        .map(|t| {
            format!(
                "\n      {{\"tenant\": \"{}\", \"priority\": {}, \"shed\": {}, \
                 \"p50\": {:.9}, \"p95\": {:.9}, \"p99\": {:.9}}}",
                t.name, t.priority, t.shed, t.p50_secs, t.p95_secs, t.p99_secs
            )
        })
        .collect();
    let slo_json = format!(
        "{{\n    \"budget_sec\": {budget:e},\n    \"no_slo_shed\": {},\n    \
         \"with_slo_shed\": {},\n    \"critical_tenant\": \"{}\",\n    \
         \"critical_shed\": {},\n    \"tenant_latency\": [{}\n    ],\n    \
         \"restart\": {{\"cold_pr_compiles\": {}, \"warm_pr_compiles\": {}, \
         \"elements\": {}}}\n  }}",
        no_slo.shed,
        with_slo.shed,
        critical.name,
        critical.shed,
        tenant_latency.join(","),
        cold.pr_compiles,
        warm.pr_compiles,
        warm.total_elements
    );

    if let Ok(path) = std::env::var("TLO_BENCH_JSON") {
        let doc = format!(
            "{{\n  \"bench\": \"serve\",\n  \"mode\": \"{}\",\n  \
             \"tenants\": {},\n  \"requests_per_tenant\": {},\n  \
             \"points\": [{}\n  ],\n  \"scaling_1_to_4\": {:.3},\n  \
             \"threshold\": 1.5,\n  \"transport\": {{\n    \
             \"protocol\": \"tagged\",\n    \"shards\": 2,\n    \
             \"elements\": {},\n    \
             \"sync_elements_per_sec\": {:.1},\n    \
             \"async_elements_per_sec\": {:.1},\n    \
             \"sync_makespan_sec\": {:.6},\n    \
             \"async_makespan_sec\": {:.6},\n    \
             \"async_vs_sync_speedup\": {:.3},\n    \
             \"threshold\": {}\n  }},\n  \"oversized\": {{\n    \
             \"grid\": \"6x6\",\n    \"shards\": 2,\n    \
             \"tenant\": \"gemm-big@u8\",\n    \
             \"tiled_tiles_per_plan\": {},\n    \
             \"baseline_makespan_sec\": {:.6},\n    \
             \"with_oversized_makespan_sec\": {:.6},\n    \
             \"cotenant_throughput_ratio\": {:.3},\n    \
             \"floor\": {}\n  }},\n  \"fleet\": {{\n    \
             \"nodes\": 4,\n    \
             \"fleet_healthy_makespan_sec\": {:.6},\n    \
             \"fleet_lossy_makespan_sec\": {:.6},\n    \
             \"fleet_crash_makespan_sec\": {:.6},\n    \
             \"fleet_lossy_retries\": {},\n    \
             \"fleet_lossy_fallback_local\": {},\n    \
             \"fleet_crash_dead_node_served\": {},\n    \
             \"fleet_crash_breaker_opens\": {},\n    \
             \"fleet_crash_survivor_served\": {}\n  }},\n  \"slo\": {}\n}}\n",
            if quick { "quick" } else { "full" },
            tenants,
            requests,
            json_rows.join(","),
            scaling,
            sync.total_elements,
            sync_eps,
            async_eps,
            sync.makespan.as_secs_f64(),
            pipe.makespan.as_secs_f64(),
            speedup,
            threshold,
            big_row.tiles,
            baseline.makespan.as_secs_f64(),
            with_big.makespan.as_secs_f64(),
            cotenant_ratio,
            floor,
            fleet_healthy.serve.makespan.as_secs_f64(),
            fleet_lossy.serve.makespan.as_secs_f64(),
            fleet_crash.serve.makespan.as_secs_f64(),
            fleet_lossy.counters.retries,
            fleet_lossy.counters.fallback_local,
            fleet_crash.nodes[0].served,
            fleet_crash.nodes[0].breaker_opens,
            crash_rest,
            slo_json
        );
        std::fs::write(&path, doc).expect("write TLO_BENCH_JSON");
        println!("wrote {path}");
    }
}

//! E1 — Table I: analysis-phase timing over the PolyBench suite.
//! Regenerates the detection/offloadability/DFG-stat rows (see
//! examples/polybench_analysis.rs for the full side-by-side table) and
//! benchmarks the analysis time, the paper's last column.

use tlo::analysis::scop::analyze_function;
use tlo::dfg::extract::extract;
use tlo::util::bench::{black_box, print_header, run, BenchConfig};
use tlo::workloads::polybench::suite;

fn main() {
    let cfg = BenchConfig::from_env();
    print_header("Table I — analysis time per PolyBench kernel");
    for k in suite() {
        run(&format!("analysis/{}", k.name), cfg, || {
            let an = analyze_function(&k.func);
            for s in &an.scops {
                let _ = black_box(extract(&k.func, s, k.unroll));
            }
            black_box(&an);
        });
    }
    println!("\n(paper analysis times: 5.5ms..107ms on their prototype; the");
    println!(" *ordering* across kernels — heat-3d slowest, syrk/trmm fastest —");
    println!(" is the reproducible shape; see EXPERIMENTS.md E1)");
}

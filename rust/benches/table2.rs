//! E2 — Table II: DFE resource utilization & Fmax per device and grid
//! size. Prints the full table (anchor rows reproduce the paper exactly;
//! other sizes are model interpolations) and the largest routable DFE per
//! device, then times the estimator.

use tlo::dfe::resource::devices;
use tlo::util::bench::{black_box, print_header, run, BenchConfig};

fn main() {
    println!("== Table II — DFE resource utilization (anchors = paper rows) ==");
    for d in devices() {
        println!("\n{} ({}, {})", d.name, d.part, d.tool.name());
        println!(
            "  {:<8} {:>9} {:>18} {:>18} {:>14}",
            "size", "Fmax", d.col_names[0], d.col_names[1], d.col_names[2]
        );
        for (r, c) in [(3, 3), (6, 6), (8, 8), (9, 9), (10, 10), (15, 15), (18, 18), (24, 18)] {
            let e = d.estimate(r, c);
            println!(
                "  {:<8} {:>6.0}MHz {:>10} ({:>4.1}%) {:>10} ({:>4.1}%) {:>7} ({:>4.1}%){}",
                format!("{r}x{c}"),
                e.fmax_mhz,
                e.ff,
                e.ff_pct,
                e.luts,
                e.lut_pct,
                e.dsp,
                e.dsp_pct,
                if e.routable { "" } else { "  [UNROUTABLE]" }
            );
        }
        let (lr, lc) = d.largest_routable();
        println!("  largest routable DFE: {lr}x{lc}");
    }

    let cfg = BenchConfig::from_env();
    print_header("Table II — estimator performance");
    run("estimate/all-devices-64-sizes", cfg, || {
        for d in devices() {
            for r in 1..=8 {
                for c in 1..=8 {
                    black_box(d.estimate(r * 3, c * 3));
                }
            }
        }
    });
}

//! E3 + E4 — Fig 6: the full §IV-C pipeline phase breakdown and the
//! software-vs-offloaded frame rate, in bench form (the interactive
//! rendition lives in examples/video_pipeline.rs).

use std::time::Duration;

use tlo::jit::engine::Engine;
use tlo::jit::interp::Memory;
use tlo::offload::{OffloadManager, OffloadParams};
use tlo::trace::Phase;
use tlo::util::bench::{print_header, run, BenchConfig};
use tlo::util::fmt_duration;
use tlo::workloads::video::{alloc_pipeline, conv_args, video_module, DECODE_MS, FrameSource, FRAME_H, FRAME_W};

fn main() {
    let cfg = BenchConfig::from_env();
    let decode = Duration::from_secs_f64(DECODE_MS * 1e-3);

    // One full pipeline run, phases recorded.
    let mut engine = Engine::new(video_module()).unwrap();
    let mut mem = Memory::new();
    let (out, inp, coef) = alloc_pipeline(&mut mem);
    let mut src = FrameSource::new();
    let mut frame = vec![0i32; FRAME_W * FRAME_H];
    let func = engine.func_index("conv").unwrap();
    for _ in 0..2 {
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        engine.call("conv", &mut mem, &conv_args(out, inp, coef)).unwrap();
    }
    let prof = engine.profile(func);
    let sw_frame =
        decode + Duration::from_secs_f64(1e-9 * prof.counters.cycles as f64 / 2.0);

    let mut mgr = OffloadManager::new(OffloadParams { min_dfg_nodes: 8, ..Default::default() });
    mgr.try_offload(&mut engine, func, None).unwrap();
    for _ in 0..8 {
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        mgr.tracer.borrow_mut().simulated(Phase::HostWork, decode);
        engine.call("conv", &mut mem, &conv_args(out, inp, coef)).unwrap();
    }
    println!("== E3: Fig-6 phase timeline (paper values in parentheses) ==");
    println!("{}", mgr.tracer.borrow().render_timeline());
    println!("paper: analysis 17.5ms, jit 16.7ms, P&R 1.18s, config 2.1ms,");
    println!("       constants 55us, PC->FPGA 35us/block, FPGA->PC 16us/block");
    let st = mgr.state(func).unwrap();
    let off_frame = decode + st.borrow().virtual_offload / st.borrow().invocations.max(1) as u32;
    println!(
        "\n== E4: frame rates ==\nsoftware {:.1} fps vs offloaded {:.1} fps  (paper: 83 vs 31)",
        1.0 / sw_frame.as_secs_f64(),
        1.0 / off_frame.as_secs_f64()
    );
    println!(
        "software frame {} / offloaded frame {}",
        fmt_duration(sw_frame),
        fmt_duration(off_frame)
    );

    // Wall-clock cost of the offloaded invocation path (gather/PJRT-or-
    // sim/scatter on this host).
    print_header("offloaded invocation wall cost (sim backend)");
    run("video/offloaded-frame", cfg, || {
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        engine.call("conv", &mut mem, &conv_args(out, inp, coef)).unwrap();
    });
}

//! E5 + A1 — PCIe transport: effective data rate under the paper's tagged
//! 128b/32b protocol (75 % overhead, ~230/4 MB/s effective) vs the
//! RIFFA-like packed protocol the paper projects "significant speed-up"
//! from, across transfer sizes (the DMA threshold crossover included).

use tlo::transport::{PcieParams, PcieSim, Protocol};
use tlo::util::bench::{black_box, print_header, run, BenchConfig};

fn main() {
    println!("== E5: effective payload rate vs transfer size ==");
    println!(
        "{:>12} {:>10} {:>16} {:>16} {:>10}",
        "payload", "mode", "tagged eff MB/s", "packed eff MB/s", "speedup"
    );
    for size in [64u64, 512, 4 << 10, 64 << 10, 1 << 20, 16 << 20] {
        let mut tagged = PcieSim::new(PcieParams::default());
        let t = tagged.transfer(size);
        let mut packed = PcieSim::new(PcieParams::riffa_like());
        let p = packed.transfer(size);
        println!(
            "{:>12} {:>10} {:>16.1} {:>16.1} {:>9.1}x",
            size,
            if t.used_dma { "DMA" } else { "PIO" },
            size as f64 / t.time.as_secs_f64() / 1e6,
            size as f64 / p.time.as_secs_f64() / 1e6,
            t.time.as_secs_f64() / p.time.as_secs_f64()
        );
    }
    println!(
        "\npaper: 230 MB/s raw link, /4 effective (75% tag overhead): model gives {:.1}%",
        Protocol::Tagged128.overhead_pct(1 << 20)
    );

    let cfg = BenchConfig::from_env();
    print_header("transport model performance");
    run("pcie/100k-transfers", cfg, || {
        let mut sim = PcieSim::new(PcieParams::default());
        for i in 0..100_000u64 {
            black_box(sim.transfer(64 + (i % 4096)));
        }
        black_box(sim.effective_rate());
    });
}

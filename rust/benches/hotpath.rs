//! §Perf — hot-path microbenchmarks for the three layers' rust-side
//! components: interpreter throughput (L3 software baseline), DFE image
//! evaluation (rust sim lane), cycle-level overlay sim, and the router.
//! Used by the performance pass; before/after numbers in EXPERIMENTS.md.

use tlo::dfe::config::fig2_config;
use tlo::dfe::image::{fig2_image, listing1_image};
use tlo::dfe::sim::simulate;
use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::Ty;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::util::bench::{black_box, print_header, run, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    print_header("L3 interpreter");
    // Inner-loop heavy kernel: ~10 bytecode ops * 100k iterations.
    let mut m = Module::new();
    let mut b = FuncBuilder::new("k", &[("A", Ty::Ptr), ("n", Ty::I32)]);
    let (a, n) = (b.param(0), b.param(1));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let v = b.load(Ty::I32, a, i);
        let w = b.mul(v, v);
        let x = b.add(w, v);
        b.store(Ty::I32, a, i, x);
    });
    m.add(b.ret(None));
    let mut engine = Engine::new(m).unwrap();
    let mut mem = Memory::new();
    let n = 100_000;
    let h = mem.alloc_i32(n);
    let s = run("interp/100k-iter-kernel", cfg, || {
        engine.call("k", &mut mem, &[Val::P(h), Val::I(n as i32)]).unwrap();
    });
    let func = engine.func_index("k").unwrap();
    let insts = engine.profile(func).counters.insts as f64
        / engine.profile(func).counters.invocations as f64;
    println!(
        "  -> {:.1} M bytecode ops/s",
        insts / s.median.as_secs_f64() / 1e6
    );

    print_header("DFE image evaluation (rust sim lane)");
    let img = fig2_image();
    let batch = 4096;
    let x: Vec<i32> = (0..2 * batch as i32).collect();
    run("image/fig2-4096-lanes", cfg, || {
        black_box(img.eval_batch(&x, batch));
    });
    let img2 = listing1_image();
    run("image/listing1-4096-lanes", cfg, || {
        black_box(img2.eval_batch(&x, batch));
    });

    print_header("cycle-level overlay simulator");
    let config = fig2_config();
    let streams: Vec<Vec<i32>> = vec![(0..512).collect(), (0..512).rev().collect()];
    run("cyclesim/fig2-512-elements", cfg, || {
        black_box(simulate(&config, &streams, 512).unwrap());
    });
}

//! §Perf — hot-path microbenchmarks for the three layers' rust-side
//! components: interpreter throughput (L3 software baseline), DFE image
//! evaluation (rust sim lane), cycle-level overlay sim, the compiled
//! wave executor (`dfe::exec`) against `CycleSim` on the PolyBench
//! streaming mix with an asserted ≥5x element-throughput speedup, and —
//! the ISSUE 10 headline — the lowered batch kernels (`dfe::lower`)
//! against the wave executor's interpreted schedule on the same mix,
//! with an asserted ≥4x speedup (relaxed under `TLO_BENCH_QUICK=1`,
//! where timings are too noisy for a hard ratio). Used by the
//! performance pass; before/after numbers in EXPERIMENTS.md.
//!
//! With `TLO_BENCH_JSON=<path>` (set by `make bench`), writes the mix
//! results as JSON so the perf trajectory is tracked across PRs.

use tlo::analysis::scop::analyze_function;
use tlo::dfe::cache::{dfg_key, spec_key, CachedConfig, SpecSignature};
use tlo::dfe::config::fig2_config;
use tlo::dfe::exec::CompiledFabric;
use tlo::dfe::grid::Grid;
use tlo::dfe::{tile_key, ExecutionPlan, LoweredKernel, PlanTile, Scratch};
use tlo::dfg::partition::{partition, TileBudget};
use tlo::dfe::image::{fig2_image, listing1_image};
use tlo::dfe::sim::CycleSim;
use tlo::dfg::extract::extract;
use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::Ty;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::offload::plan_invocation_time;
use tlo::par::{place_and_route, ParParams};
use tlo::transport::{PcieParams, TransportMode};
use tlo::util::bench::{black_box, print_header, run, BenchConfig};
use tlo::util::json::escape;
use tlo::util::prng::Rng;
use tlo::workloads::polybench;

/// One routed PolyBench kernel of the streaming mix.
struct MixCase {
    name: &'static str,
    config: tlo::dfe::GridConfig,
    fabric: CompiledFabric,
    streams: Vec<Vec<i32>>,
}

/// Route the serve-layer mix kernels (gemm / trmm / syr2k / gesummv,
/// unroll 2 — the same extractions `OffloadServer` schedules) onto an
/// 8x8 overlay and prepare random input streams of `n` elements.
fn build_mix(n: usize) -> Vec<MixCase> {
    let kernels: [(&'static str, fn() -> tlo::ir::func::Function); 4] = [
        ("gemm", polybench::gemm),
        ("trmm", polybench::trmm),
        ("syr2k", polybench::syr2k),
        ("gesummv", polybench::gesummv),
    ];
    let mut mix = Vec::new();
    for (i, (name, func)) in kernels.into_iter().enumerate() {
        let f = func();
        let an = analyze_function(&f);
        let Some(scop) = an.scops.first() else {
            println!("  (skipping {name}: no SCoP)");
            continue;
        };
        let Ok(off) = extract(&f, scop, 2) else {
            println!("  (skipping {name}: not extractable)");
            continue;
        };
        let mut rng = Rng::new(0xBE9C + i as u64);
        let Ok(res) = place_and_route(&off.dfg, Grid::new(8, 8), &ParParams::default(), &mut rng)
        else {
            println!("  (skipping {name}: unroutable on 8x8)");
            continue;
        };
        let fabric = CompiledFabric::compile(&res.config)
            .expect("routed configs lower to a wave schedule");
        let mut t = Rng::new(77 * i as u64 + 1);
        let streams: Vec<Vec<i32>> = (0..fabric.n_inputs)
            .map(|_| (0..n).map(|_| t.any_i32() % 100_000).collect())
            .collect();
        mix.push(MixCase { name, config: res.config, fabric, streams });
    }
    mix
}

fn main() {
    let cfg = BenchConfig::from_env();
    print_header("L3 interpreter");
    // Inner-loop heavy kernel: ~10 bytecode ops * 100k iterations.
    let mut m = Module::new();
    let mut b = FuncBuilder::new("k", &[("A", Ty::Ptr), ("n", Ty::I32)]);
    let (a, n) = (b.param(0), b.param(1));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let v = b.load(Ty::I32, a, i);
        let w = b.mul(v, v);
        let x = b.add(w, v);
        b.store(Ty::I32, a, i, x);
    });
    m.add(b.ret(None));
    let mut engine = Engine::new(m).unwrap();
    let mut mem = Memory::new();
    let n = 100_000;
    let h = mem.alloc_i32(n);
    let s = run("interp/100k-iter-kernel", cfg, || {
        engine.call("k", &mut mem, &[Val::P(h), Val::I(n as i32)]).unwrap();
    });
    let func = engine.func_index("k").unwrap();
    let insts = engine.profile(func).counters.insts as f64
        / engine.profile(func).counters.invocations as f64;
    println!(
        "  -> {:.1} M bytecode ops/s",
        insts / s.median.as_secs_f64() / 1e6
    );

    print_header("DFE image evaluation (rust sim lane)");
    let img = fig2_image();
    let batch = 4096;
    let x: Vec<i32> = (0..2 * batch as i32).collect();
    run("image/fig2-4096-lanes", cfg, || {
        black_box(img.eval_batch(&x, batch));
    });
    let img2 = listing1_image();
    run("image/listing1-4096-lanes", cfg, || {
        black_box(img2.eval_batch(&x, batch));
    });

    print_header("cycle-level overlay simulator (fig2 reference)");
    let config = fig2_config();
    let streams: Vec<Vec<i32>> = vec![(0..512).collect(), (0..512).rev().collect()];
    run("cyclesim/fig2-512-elements", cfg, || {
        black_box(
            CycleSim::new(&config).unwrap().run_stream(&streams, 512).unwrap(),
        );
    });
    let fig2_fabric = CompiledFabric::compile(&config).unwrap();
    run("wave/fig2-512-elements", cfg, || {
        black_box(fig2_fabric.run_stream(&streams, 512).unwrap());
    });

    // ---- the headline: wave executor vs CycleSim, PolyBench mix ----
    let quick = cfg.iters <= 3;
    let n_elems: usize = if quick { 512 } else { 4096 };
    print_header("wave executor vs CycleSim — PolyBench streaming mix");
    let mix = build_mix(n_elems);
    assert!(
        mix.len() >= 3,
        "only {}/4 mix kernels routed — the speedup measurement would be \
         unrepresentative",
        mix.len()
    );

    struct Row {
        name: &'static str,
        cyc_s: f64,
        wave_s: f64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for case in &mix {
        // Outputs must agree before their speeds are comparable.
        let want = CycleSim::new(&case.config)
            .unwrap()
            .run_stream(&case.streams, n_elems)
            .unwrap();
        let got = case.fabric.run_stream(&case.streams, n_elems).unwrap();
        assert_eq!(got.outputs, want.outputs, "{}: engines diverge", case.name);

        let c = run(&format!("cyclesim/{}-{}el", case.name, n_elems), cfg, || {
            black_box(
                CycleSim::new(&case.config)
                    .unwrap()
                    .run_stream(&case.streams, n_elems)
                    .unwrap(),
            );
        });
        let w = run(&format!("wave/{}-{}el", case.name, n_elems), cfg, || {
            black_box(case.fabric.run_stream(&case.streams, n_elems).unwrap());
        });
        rows.push(Row {
            name: case.name,
            cyc_s: c.median.as_secs_f64(),
            wave_s: w.median.as_secs_f64(),
        });
    }

    println!(
        "\n{:<10} {:>16} {:>16} {:>9}",
        "kernel", "cyclesim el/s", "wave el/s", "speedup"
    );
    let (mut cyc_total, mut wave_total) = (0.0f64, 0.0f64);
    for r in &rows {
        cyc_total += r.cyc_s;
        wave_total += r.wave_s;
        println!(
            "{:<10} {:>16.0} {:>16.0} {:>8.1}x",
            r.name,
            n_elems as f64 / r.cyc_s,
            n_elems as f64 / r.wave_s,
            r.cyc_s / r.wave_s
        );
    }
    let speedup = cyc_total / wave_total;
    println!(
        "\naggregate element throughput speedup: {speedup:.1}x (acceptance: >= 5x)"
    );
    assert!(
        speedup >= 5.0,
        "wave executor speedup {speedup:.2}x below the 5x acceptance threshold"
    );
    println!("PASS: compiled wave executor is {speedup:.1}x CycleSim on the mix");

    // ---- lowered batch kernels vs the interpreted wave schedule ----
    // Both sides run the batch ABI entry point (`run_batch`) — the exact
    // call the offload stub makes — so the ratio isolates what the
    // lowering buys: specialized per-op sweeps instead of a per-lane
    // `Op::eval` match, folded/fused steps, and a reusable scratch arena
    // instead of per-invocation buffer allocation + const refill.
    print_header("lowered batch kernels vs wave executor — PolyBench streaming mix");
    struct LRow {
        name: &'static str,
        waveb_s: f64,
        low_s: f64,
        folded: usize,
        fused: usize,
    }
    let mut lrows: Vec<LRow> = Vec::new();
    let mut scratch = Scratch::new();
    for case in &mix {
        let kernel = LoweredKernel::lower(&case.fabric);
        let n_in = case.fabric.n_inputs;
        let mut x = vec![0i32; n_in * n_elems];
        for (j, s) in case.streams.iter().take(n_in).enumerate() {
            x[j * n_elems..(j + 1) * n_elems].copy_from_slice(s);
        }
        // Outputs must agree before their speeds are comparable.
        let want = case.fabric.run_batch(&x, n_elems);
        assert_eq!(
            kernel.run_batch(&x, n_elems, &mut scratch),
            want,
            "{}: lowered kernel diverges from the wave executor",
            case.name
        );

        let w = run(&format!("waveb/{}-{}el", case.name, n_elems), cfg, || {
            black_box(case.fabric.run_batch(&x, n_elems));
        });
        let l = run(&format!("lowered/{}-{}el", case.name, n_elems), cfg, || {
            black_box(kernel.run_batch(&x, n_elems, &mut scratch));
        });
        lrows.push(LRow {
            name: case.name,
            waveb_s: w.median.as_secs_f64(),
            low_s: l.median.as_secs_f64(),
            folded: kernel.folded,
            fused: kernel.fused,
        });
    }

    println!(
        "\n{:<10} {:>16} {:>16} {:>9} {:>7} {:>6}",
        "kernel", "wave el/s", "lowered el/s", "speedup", "folded", "fused"
    );
    let (mut waveb_total, mut low_total) = (0.0f64, 0.0f64);
    for r in &lrows {
        waveb_total += r.waveb_s;
        low_total += r.low_s;
        println!(
            "{:<10} {:>16.0} {:>16.0} {:>8.1}x {:>7} {:>6}",
            r.name,
            n_elems as f64 / r.waveb_s,
            n_elems as f64 / r.low_s,
            r.waveb_s / r.low_s,
            r.folded,
            r.fused
        );
    }
    let lowered_speedup = waveb_total / low_total;
    // Quick mode runs too few iterations (and too few elements) for a
    // stable ratio; it only guards against a regression to slower-than-
    // interpreted. The real ≥4x acceptance gate runs in full mode.
    let lowered_threshold = if quick { 1.2 } else { 4.0 };
    println!(
        "\naggregate lowered-vs-wave speedup: {lowered_speedup:.1}x \
         (acceptance: >= {lowered_threshold}x{})",
        if quick { ", quick mode" } else { "" }
    );
    assert!(
        lowered_speedup >= lowered_threshold,
        "lowered kernel speedup {lowered_speedup:.2}x below the \
         {lowered_threshold}x acceptance threshold"
    );
    println!("PASS: lowered batch kernels are {lowered_speedup:.1}x the wave executor");

    // ---- tiled execution plans: multi-pass overlap on an undersized grid ----
    // gemm at unroll 8 carries more calc nodes than a 3x3 overlay has
    // cells; the partitioner cuts it into a feed-forward plan and the
    // async transport overlaps tile N+1's upload with tile N's execute.
    print_header("tiled plan — gemm@u8 time-multiplexed over a 3x3 overlay");
    let f = polybench::gemm();
    let an = analyze_function(&f);
    let scop = an.scops.first().expect("gemm has a SCoP");
    let off = extract(&f, scop, 8).expect("gemm extracts at unroll 8");
    let tile_grid = Grid::new(3, 3);
    let tiled = partition(&off.dfg, TileBudget::for_grid(tile_grid))
        .expect("gemm@u8 partitions under the 3x3 budget");
    assert!(tiled.n_tiles() > 1, "gemm@u8 must not fit a 3x3 overlay in one tile");
    let plan_key = spec_key(dfg_key(&off.dfg), SpecSignature::generic(8));
    let mut ptiles = Vec::with_capacity(tiled.n_tiles());
    for (idx, t) in tiled.tiles.iter().enumerate() {
        let mut routed = None;
        // Las-Vegas P&R: a single seed may fail on a legal tile.
        for seed in 0..64u64 {
            let mut rng = Rng::new(0x71E5 + seed * 997 + idx as u64);
            if let Ok(res) =
                place_and_route(&t.dfg, tile_grid, &ParParams::default(), &mut rng)
            {
                routed = Some(res);
                break;
            }
        }
        let res = routed.expect("every cut tile fits its budget and routes");
        let image = res.config.to_image().expect("routed tiles lower to images");
        ptiles.push(PlanTile {
            cached: CachedConfig::new(res.config, image, format!("tile{idx}_3x3")),
            sources: t.sources.clone(),
            sinks: t.sinks.clone(),
            key: tile_key(plan_key, idx, dfg_key(&t.dfg)),
        });
    }
    let plan = ExecutionPlan { tiles: ptiles, n_spills: tiled.n_spills };
    let batch = n_elems as u64;
    let link = PcieParams::default();
    let fmax = 150.0e6;
    let plan_sync = plan_invocation_time(&plan, 8, batch, fmax, (link, TransportMode::Sync));
    let plan_async =
        plan_invocation_time(&plan, 8, batch, fmax, (link, TransportMode::async_default()));
    let overlap = plan_sync.as_secs_f64() / plan_async.as_secs_f64().max(1e-12);
    println!(
        "  {} tiles, {} spill streams; modeled makespan for {batch} elements: \
         sync {plan_sync:?}  async {plan_async:?}  overlap {overlap:.2}x",
        plan.n_tiles(),
        plan.n_spills,
    );
    assert!(
        plan_async <= plan_sync,
        "multi-pass overlap must never lose: async {plan_async:?} vs sync {plan_sync:?}"
    );
    println!("PASS: async multi-pass makespan <= sync over {} tiles", plan.n_tiles());

    // ---- static verifier overhead (DESIGN.md §11) ----
    // The verify-on-insert hook runs under debug_assertions only; this
    // measures what that debug tax costs per artifact (and what a release
    // `tlo lint` pays per kernel), so the trajectory JSON catches the
    // verifier silently growing superlinear.
    print_header("static verifier — re-verification cost per artifact");
    let artifacts: Vec<(&str, CachedConfig)> = mix
        .iter()
        .map(|c| {
            let image = c.config.to_image().expect("mix configs lower");
            (c.name, CachedConfig::new(c.config.clone(), image, format!("verify_{}", c.name)))
        })
        .collect();
    let reps = if quick { 5u32 } else { 50 };
    let mut verify_clean = true;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        for (_, a) in &artifacts {
            let diags = tlo::analysis::verifier::verify_artifact(black_box(a));
            verify_clean &= !tlo::analysis::diag::has_errors(&diags);
            black_box(diags);
        }
    }
    let verify_artifact_micros =
        t0.elapsed().as_secs_f64() * 1e6 / (reps as usize * artifacts.len()) as f64;
    let t1 = std::time::Instant::now();
    for _ in 0..reps {
        let diags = tlo::analysis::verifier::verify_plan(black_box(&plan));
        verify_clean &= !tlo::analysis::diag::has_errors(&diags);
        black_box(diags);
    }
    let verify_plan_micros = t1.elapsed().as_secs_f64() * 1e6 / reps as f64;
    println!(
        "  verify_artifact: {verify_artifact_micros:.1} us/artifact over {} mix configs; \
         verify_plan: {verify_plan_micros:.1} us/plan over {} tiles; clean: {verify_clean}",
        artifacts.len(),
        plan.n_tiles(),
    );
    assert!(verify_clean, "benchmarked artifacts must verify clean");

    // ---- perf-trajectory JSON (written by `make bench`) ----
    if let Ok(path) = std::env::var("TLO_BENCH_JSON") {
        let mut kernels = String::new();
        for (i, (r, lr)) in rows.iter().zip(&lrows).enumerate() {
            if i > 0 {
                kernels.push(',');
            }
            kernels.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"cyclesim_elements_per_sec\": {:.1}, \
                 \"wave_elements_per_sec\": {:.1}, \"speedup\": {:.3}, \
                 \"lowered_elements_per_sec\": {:.1}, \
                 \"lowered_vs_wave_speedup\": {:.3}, \
                 \"lowered_folded_firings\": {}, \"lowered_fused_edges\": {}}}",
                escape(r.name),
                n_elems as f64 / r.cyc_s,
                n_elems as f64 / r.wave_s,
                r.cyc_s / r.wave_s,
                n_elems as f64 / lr.low_s,
                lr.waveb_s / lr.low_s,
                lr.folded,
                lr.fused
            ));
        }
        let doc = format!(
            "{{\n  \"bench\": \"hotpath\",\n  \"mode\": \"{}\",\n  \
             \"elements\": {},\n  \"kernels\": [{}\n  ],\n  \
             \"aggregate_speedup\": {:.3},\n  \"threshold\": 5.0,\n  \
             \"lowered_aggregate_speedup\": {:.3},\n  \
             \"lowered_threshold\": {:.1},\n  \
             \"tiled_kernel\": \"gemm@u8/3x3\",\n  \
             \"tiled_tiles_per_plan\": {},\n  \"tiled_spill_streams\": {},\n  \
             \"tiled_makespan_sync_secs\": {:.9},\n  \
             \"tiled_makespan_async_secs\": {:.9},\n  \
             \"tiled_overlap_efficiency\": {:.3},\n  \
             \"verify_artifact_micros\": {:.3},\n  \
             \"verify_plan_micros\": {:.3},\n  \
             \"verify_clean\": {}\n}}\n",
            if quick { "quick" } else { "full" },
            n_elems,
            kernels,
            speedup,
            lowered_speedup,
            lowered_threshold,
            plan.n_tiles(),
            plan.n_spills,
            plan_sync.as_secs_f64(),
            plan_async.as_secs_f64(),
            overlap,
            verify_artifact_micros,
            verify_plan_micros,
            verify_clean
        );
        std::fs::write(&path, doc).expect("write TLO_BENCH_JSON");
        println!("wrote {path}");
    }
}

//! A3 — rollback crossover: sweep the workload size for the Fig-2 kernel
//! and report where the offloaded (transfer-bound) path beats or loses to
//! software, under both PCIe protocols. This regenerates the economics
//! behind the paper's DFG-size threshold and its 31-vs-83-fps result.

use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::Ty;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::offload::{OffloadManager, OffloadParams};
use tlo::transport::PcieParams;

fn fig2_module() -> Module {
    let mut m = Module::new();
    let mut b = FuncBuilder::new(
        "fig2",
        &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
    );
    let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let av = b.load(Ty::I32, a, i);
        let bv = b.load(Ty::I32, bb, i);
        let c3 = b.const_i32(3);
        let t = b.mul(bv, c3);
        let s = b.add(av, t);
        let c1 = b.const_i32(1);
        let r = b.add(s, c1);
        b.store(Ty::I32, c, i, r);
    });
    m.add(b.ret(None));
    m
}

fn verdict(n: usize, pcie: PcieParams) -> (f64, f64, bool) {
    let mut engine = Engine::new(fig2_module()).unwrap();
    let mut mem = Memory::new();
    let (ha, hb, hc) = (mem.alloc_i32(n), mem.alloc_i32(n), mem.alloc_i32(n));
    let args = [Val::P(hc), Val::P(ha), Val::P(hb), Val::I(n as i32)];
    engine.call("fig2", &mut mem, &args).unwrap();
    let func = engine.func_index("fig2").unwrap();
    let sw = 1e-9 * engine.profile(func).counters.cycles as f64;

    let mut mgr = OffloadManager::new(OffloadParams {
        min_dfg_nodes: 1,
        unroll: 4,
        rollback_window: 2,
        pcie,
        ..Default::default()
    });
    mgr.try_offload(&mut engine, func, None).unwrap();
    for _ in 0..3 {
        engine.call("fig2", &mut mem, &args).unwrap();
    }
    let st = mgr.state(func).unwrap();
    let off = st.borrow().virtual_offload.as_secs_f64() / st.borrow().invocations as f64;
    let rolled = !mgr.check_rollback(&mut engine).is_empty();
    (sw, off, rolled)
}

fn main() {
    println!("== A3: offload-vs-software crossover (fig2 kernel, unroll 4) ==");
    println!(
        "{:>10} {:>14} {:>14} {:>10} | {:>14} {:>10}",
        "n", "software", "tagged off", "verdict", "packed off", "verdict"
    );
    for n in [256usize, 1024, 4096, 16384, 65536, 262144] {
        let (sw, off_t, rolled_t) = verdict(n, PcieParams::default());
        let (_, off_p, rolled_p) = verdict(n, PcieParams::riffa_like());
        println!(
            "{:>10} {:>12.1}us {:>12.1}us {:>10} | {:>12.1}us {:>10}",
            n,
            sw * 1e6,
            off_t * 1e6,
            if rolled_t { "ROLLBACK" } else { "keep" },
            off_p * 1e6,
            if rolled_p { "ROLLBACK" } else { "keep" },
        );
    }
    println!("\nshape check: the tagged protocol loses everywhere transfer-bound");
    println!("(the paper's 31 < 83 fps); the packed protocol flips the verdict");
    println!("at large n — the \"significant speed-up\" the paper projects.");
}

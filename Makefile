# Top-level convenience targets. The one that matters at build time:
#
#   make artifacts   AOT-lower the Pallas DFE datapath (python/compile/aot.py)
#                    to HLO-text artifacts + manifest.json under ./artifacts,
#                    which the rust runtime loads via PJRT. Without it the
#                    binary falls back to the rust functional simulator and
#                    rust/tests/runtime_artifacts.rs skips.

PYTHON ?= python3

.PHONY: artifacts build test bench clean

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q
	$(PYTHON) -m pytest python/tests -q

bench:
	cargo bench

clean:
	rm -rf target rust/target artifacts

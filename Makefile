# Top-level convenience targets. The one that matters at build time:
#
#   make artifacts   AOT-lower the Pallas DFE datapath (python/compile/aot.py)
#                    to HLO-text artifacts + manifest.json under ./artifacts,
#                    which the rust runtime loads via PJRT. Without it the
#                    binary falls back to the rust functional simulator and
#                    rust/tests/runtime_artifacts.rs skips.
#
#   make bench       Run the harness=false benches in a fixed order and
#                    write BENCH_dfe.json (wave executor vs CycleSim,
#                    elements/sec + asserted >=5x speedup),
#                    BENCH_serve.json (shard scaling + the A7 sync-vs-
#                    async transport ablation, asserted >=1.3x),
#                    BENCH_transport.json (the deterministic pipeline
#                    model) and BENCH_par.json (the A8 portfolio-K race
#                    vs single-seed P&R p50/p95 + warm-start win rate,
#                    asserted >=2x p95 / >=80% wins in full mode) at the
#                    repo root, so the perf trajectory is tracked across
#                    PRs. The BENCH_*.json files are committed — re-run
#                    `make bench` to refresh them. Set TLO_BENCH_QUICK=1
#                    for the CI smoke run (small n, relaxed thresholds,
#                    same assertions).

#   make chaos       Drive the fleet layer under a lossy fault profile:
#                    `tlo serve --fleet` on a mixed drop/dup/reorder/
#                    jitter/crash schedule (replayable from the fixed
#                    --fault-seed), then the tests/fleet.rs chaos suite
#                    and the P10 reliability property. Zero panics and
#                    oracle-verified outputs are the acceptance bar.

#   make lint        Style + static-analysis gate (mirrors the CI `lint`
#                    suite): rustfmt in check mode and clippy over every
#                    target with warnings promoted to errors. The clippy
#                    run also enforces the unwrap audit (clippy.toml
#                    disallowed_methods, opted into by the serve / fleet /
#                    persist hot paths). `tlo lint` — the artifact
#                    verifier sweep over the PolyBench suite — is the
#                    runtime half; CI runs it in the `verifier` suite.

PYTHON ?= python3

.PHONY: artifacts build test bench chaos lint clean

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q
	$(PYTHON) -m pytest python/tests -q

# Fixed order: the four JSON-emitting trajectory benches first, then the
# paper-table/figure regenerators.
bench:
	TLO_BENCH_JSON=$(CURDIR)/BENCH_dfe.json cargo bench --bench hotpath
	TLO_BENCH_JSON=$(CURDIR)/BENCH_serve.json cargo bench --bench serve_bench
	TLO_BENCH_JSON=$(CURDIR)/BENCH_transport.json cargo bench --bench transport_bench
	TLO_BENCH_JSON=$(CURDIR)/BENCH_par.json cargo bench --bench par_bench
	cargo bench --bench pcie_transport
	cargo bench --bench rollback_bench
	cargo bench --bench fig6_phases
	cargo bench --bench table1
	cargo bench --bench table2

lint:
	cargo fmt --check
	cargo clippy --all-targets -- -D warnings

chaos:
	cargo run --release -- serve --tenants 4 --shards 2 --requests 6 --fleet 4 --fault-profile drop=0.2,dup=0.2,reorder=0.2,jitter=0.3,crash=0.05 --fault-seed 51966
	cargo run --release -- serve --tenants 4 --shards 2 --requests 6 --fleet 2 --fault-profile drop=1.0 --fault-seed 7
	cargo test -q --test fleet
	cargo test -q --test proptests p10_

clean:
	rm -rf target rust/target artifacts

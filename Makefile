# Top-level convenience targets. The one that matters at build time:
#
#   make artifacts   AOT-lower the Pallas DFE datapath (python/compile/aot.py)
#                    to HLO-text artifacts + manifest.json under ./artifacts,
#                    which the rust runtime loads via PJRT. Without it the
#                    binary falls back to the rust functional simulator and
#                    rust/tests/runtime_artifacts.rs skips.
#
#   make bench       Run the harness=false benches in a fixed order and
#                    write BENCH_dfe.json (wave executor vs CycleSim,
#                    elements/sec + asserted >=5x speedup),
#                    BENCH_serve.json (shard scaling + the A7 sync-vs-
#                    async transport ablation, asserted >=1.3x),
#                    BENCH_transport.json (the deterministic pipeline
#                    model) and BENCH_par.json (the A8 portfolio-K race
#                    vs single-seed P&R p50/p95 + warm-start win rate,
#                    asserted >=2x p95 / >=80% wins in full mode) at the
#                    repo root, so the perf trajectory is tracked across
#                    PRs. The BENCH_*.json files are committed — re-run
#                    `make bench` to refresh them. Set TLO_BENCH_QUICK=1
#                    for the CI smoke run (small n, relaxed thresholds,
#                    same assertions).

PYTHON ?= python3

.PHONY: artifacts build test bench clean

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q
	$(PYTHON) -m pytest python/tests -q

# Fixed order: the four JSON-emitting trajectory benches first, then the
# paper-table/figure regenerators.
bench:
	TLO_BENCH_JSON=$(CURDIR)/BENCH_dfe.json cargo bench --bench hotpath
	TLO_BENCH_JSON=$(CURDIR)/BENCH_serve.json cargo bench --bench serve_bench
	TLO_BENCH_JSON=$(CURDIR)/BENCH_transport.json cargo bench --bench transport_bench
	TLO_BENCH_JSON=$(CURDIR)/BENCH_par.json cargo bench --bench par_bench
	cargo bench --bench pcie_transport
	cargo bench --bench rollback_bench
	cargo bench --bench fig6_phases
	cargo bench --bench table1
	cargo bench --bench table2

clean:
	rm -rf target rust/target artifacts

"""L2 model + AOT lowering tests: shapes, variant table, HLO text validity."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import opcodes as op


def test_variant_table():
    names = [v.name for v in model.VARIANTS]
    assert names == ["dfe_4x4", "dfe_8x8", "dfe_12x12", "dfe_15x15", "dfe_24x18"]
    assert model.VARIANTS[-1].n_cells == 432  # the paper's largest DFE
    for v in model.VARIANTS:
        assert v.n_slots == 1 + model.N_CONSTS + model.N_INPUTS + v.n_cells


def test_model_executes_smallest_variant():
    v = model.VARIANTS[0]
    n = v.n_cells
    base = 1 + model.N_CONSTS + model.N_INPUTS
    in0 = 1 + model.N_CONSTS
    opcode = np.zeros(n, np.int32)
    opcode[0] = op.ADD
    src1 = np.zeros(n, np.int32)
    src2 = np.zeros(n, np.int32)
    src1[0], src2[0] = in0, 1  # x[0] + consts[0]
    sel = np.zeros(n, np.int32)
    consts = np.zeros(model.N_CONSTS, np.int32)
    consts[0] = 41
    out_sel = np.zeros(model.N_OUTPUTS, np.int32)
    out_sel[0] = base
    x = np.ones((model.N_INPUTS, model.BATCH), np.int32)
    (out,) = model.jitted(v)(
        *[jnp.asarray(a) for a in (opcode, src1, src2, sel, consts, out_sel, x)]
    )
    assert out.shape == (model.N_OUTPUTS, model.BATCH)
    assert (np.asarray(out)[0] == 42).all()
    assert (np.asarray(out)[1:] == 0).all()


def test_hlo_text_lowering_smallest():
    """HLO text (not proto) — must contain an ENTRY and i32 tensors and
    carry no Mosaic custom-call (interpret=True requirement)."""
    text = aot.lower_variant(model.VARIANTS[0])
    assert "ENTRY" in text
    assert "s32" in text
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_example_args_match_fn():
    for v in model.VARIANTS[:2]:
        args = model.example_args(v)
        assert args[0].shape == (v.n_cells,)
        assert args[-1].shape == (model.N_INPUTS, model.BATCH)

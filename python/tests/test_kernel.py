"""Kernel-vs-oracle correctness: the CORE numeric signal of the repo.

Three implementations of the DFE execution-image semantics are compared:
  1. the L1 Pallas kernel (interpret=True) — what ships in the artifacts,
  2. ref.ref_apply — vectorized jnp oracle,
  3. ref.py_apply — independently written scalar-python oracle.
Hypothesis sweeps random-but-legal execution images (topological sources),
grid sizes, batch contents including i32 extremes.
"""

from __future__ import annotations

import numpy as np
import pytest

# The offline image may lack hypothesis; skip this module (not the whole
# suite) rather than erroring at collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import opcodes as op
from compile.kernels.dfe_grid import BLOCK_BATCH, dfe_apply
from compile.kernels.ref import py_apply, ref_apply, validate_image

N_CONSTS = 4
N_INPUTS = 6
N_OUTPUTS = 3


def run_all(opcode, src1, src2, sel, consts, out_sel, x):
    args = [np.asarray(a, np.int32) for a in (opcode, src1, src2, sel, consts, out_sel, x)]
    validate_image(*args[:6], n_inputs=args[6].shape[0])
    got_pallas = np.asarray(
        dfe_apply(
            *args,
            n_cells=args[0].shape[0],
            n_consts=args[4].shape[0],
            n_inputs=args[6].shape[0],
            n_outputs=args[5].shape[0],
        )
    )
    got_ref = np.asarray(ref_apply(*args))
    np.testing.assert_array_equal(got_pallas, got_ref)
    return got_pallas


@st.composite
def exec_images(draw):
    """Random legal execution image + batch (batch == BLOCK_BATCH lanes)."""
    n_cells = draw(st.integers(min_value=1, max_value=24))
    base = 1 + N_CONSTS + N_INPUTS
    i32 = st.integers(min_value=-(2**31), max_value=2**31 - 1)

    opcode, src1, src2, sel = [], [], [], []
    for i in range(n_cells):
        limit = base + i
        opcode.append(draw(st.integers(min_value=0, max_value=op.NUM_OPS - 1)))
        src1.append(draw(st.integers(min_value=0, max_value=limit - 1)))
        src2.append(draw(st.integers(min_value=0, max_value=limit - 1)))
        sel.append(draw(st.integers(min_value=0, max_value=limit - 1)))
    consts = draw(
        st.lists(i32, min_size=N_CONSTS, max_size=N_CONSTS)
    )
    out_sel = draw(
        st.lists(
            st.integers(min_value=0, max_value=base + n_cells - 1),
            min_size=N_OUTPUTS, max_size=N_OUTPUTS,
        )
    )
    # A few interesting lanes + random fill.
    lanes = draw(
        st.lists(
            st.lists(i32, min_size=N_INPUTS, max_size=N_INPUTS),
            min_size=1, max_size=4,
        )
    )
    x = np.zeros((N_INPUTS, BLOCK_BATCH), np.int64)
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    x[:, :] = rng.integers(-(2**31), 2**31, size=(N_INPUTS, BLOCK_BATCH))
    for j, lane in enumerate(lanes):
        x[:, j] = lane
    return (
        np.array(opcode, np.int32),
        np.array(src1, np.int32),
        np.array(src2, np.int32),
        np.array(sel, np.int32),
        np.array(consts, np.int32),
        np.array(out_sel, np.int32),
        x.astype(np.int32),
    )


@settings(max_examples=30, deadline=None)
@given(exec_images())
def test_pallas_matches_jnp_oracle(image):
    run_all(*image)


@settings(max_examples=10, deadline=None)
@given(exec_images())
def test_jnp_oracle_matches_scalar_python(image):
    """Cross-check the two oracles on a handful of lanes (py_apply is slow)."""
    opcode, src1, src2, sel, consts, out_sel, x = image
    x_small = x[:, :8].copy()
    got = np.asarray(ref_apply(*[np.asarray(a, np.int32) for a in
                                 (opcode, src1, src2, sel, consts, out_sel)], x_small))
    want = py_apply(opcode, src1, src2, sel, consts, out_sel, x_small)
    np.testing.assert_array_equal(got, want)


def _image_a_plus_3b_plus_1():
    """Fig 2's C = A + 3B + 1 as an execution image: inputs a=slot in0,
    b=in1; consts 3 (c0) and 1 (c1)."""
    base = 1 + N_CONSTS + N_INPUTS
    in0, in1 = 1 + N_CONSTS, 1 + N_CONSTS + 1
    c3, c1 = 1, 2  # const-pool slot for consts[k] is 1 + k
    opcode = [op.MUL, op.ADD, op.ADD]
    src1 = [in1, in0, base + 1]
    src2 = [c3, base + 0, c1]
    sel = [0, 0, 0]
    consts = [3, 1, 0, 0]
    out_sel = [base + 2, 0, 0]
    return opcode, src1, src2, sel, consts, out_sel


def test_fig2_a_plus_3b_plus_1():
    opcode, src1, src2, sel, consts, out_sel = _image_a_plus_3b_plus_1()
    rng = np.random.default_rng(7)
    x = rng.integers(-1000, 1000, size=(N_INPUTS, BLOCK_BATCH)).astype(np.int32)
    got = run_all(opcode, src1, src2, sel, consts, out_sel, x)
    a, b = x[0].astype(np.int64), x[1].astype(np.int64)
    np.testing.assert_array_equal(got[0], (a + 3 * b + 1).astype(np.int32))


def test_listing1_branchy_mux():
    """Listing 1 / Fig 4: C = (A>B) ? A+3B+1 : A-5B-2 via CMP + MUX."""
    base = 1 + N_CONSTS + N_INPUTS
    in_a, in_b = 1 + N_CONSTS, 1 + N_CONSTS + 1
    consts = [3, 1, 5, 2]
    c3, c1, c5, c2 = 1, 2, 3, 4
    opcode = [op.GT, op.MUL, op.ADD, op.ADD, op.MUL, op.SUB, op.SUB, op.MUX]
    #          0      1       2       3       4       5       6       7
    src1 = [in_a, in_b, in_a, base + 2, in_b, in_a, base + 5, base + 3]
    src2 = [in_b, c3, base + 1, c1, c5, base + 4, c2, base + 6]
    sel = [0, 0, 0, 0, 0, 0, 0, base + 0]
    out_sel = [base + 7, 0, 0]
    rng = np.random.default_rng(11)
    x = rng.integers(-100, 100, size=(N_INPUTS, BLOCK_BATCH)).astype(np.int32)
    got = run_all(opcode, src1, src2, sel, consts, out_sel, x)
    a, b = x[0].astype(np.int64), x[1].astype(np.int64)
    want = np.where(a > b, a + 3 * b + 1, a - 5 * b - 2).astype(np.int32)
    np.testing.assert_array_equal(got[0], want)


def test_i32_wrapping():
    """MUL/ADD wrap like the 32-bit signed FPGA datapath."""
    base = 1 + N_CONSTS + N_INPUTS
    in0 = 1 + N_CONSTS
    opcode = [op.MUL, op.ADD]
    src1 = [in0, base + 0]
    src2 = [in0, base + 0]
    sel = [0, 0]
    consts = [0] * N_CONSTS
    out_sel = [base + 0, base + 1, 0]
    x = np.full((N_INPUTS, BLOCK_BATCH), 2**30, np.int32)
    got = run_all(opcode, src1, src2, sel, consts, out_sel, x)
    want_mul = np.int32((2**60) % (2**32))  # == 0 after wrap
    assert (got[0] == want_mul).all()


def test_shift_clamping():
    """Shift amounts outside [0,31] clamp rather than poisoning lanes."""
    base = 1 + N_CONSTS + N_INPUTS
    in0, in1 = 1 + N_CONSTS, 1 + N_CONSTS + 1
    opcode = [op.SHL, op.SHR]
    src1 = [in0, in0]
    src2 = [in1, in1]
    sel = [0, 0]
    consts = [0] * N_CONSTS
    out_sel = [base + 0, base + 1, 0]
    x = np.zeros((N_INPUTS, BLOCK_BATCH), np.int32)
    x[0, :] = -64
    x[1, :4] = [40, -3, 31, 0]
    got = run_all(opcode, src1, src2, sel, consts, out_sel, x)
    # shamt clamps to 31, 0, 31, 0
    assert got[0, 0] == np.int32(np.left_shift(np.int32(-64), 31))
    assert got[0, 1] == -64
    assert got[1, 0] == -1  # arithmetic shift of negative
    assert got[1, 3] == -64


def test_multi_block_batch():
    """Batches spanning several BlockSpec tiles stitch together correctly."""
    batch = BLOCK_BATCH * 4
    base = 1 + N_CONSTS + N_INPUTS
    in0 = 1 + N_CONSTS
    opcode = np.array([op.ADD], np.int32)
    src1 = np.array([in0], np.int32)
    src2 = np.array([1], np.int32)  # const slot
    sel = np.array([0], np.int32)
    consts = np.array([100, 0, 0, 0], np.int32)
    out_sel = np.array([base, 0, 0], np.int32)
    x = np.arange(N_INPUTS * batch, dtype=np.int32).reshape(N_INPUTS, batch)
    got = np.asarray(
        dfe_apply(
            opcode, src1, src2, sel, consts, out_sel, x,
            n_cells=1, n_consts=N_CONSTS, n_inputs=N_INPUTS, n_outputs=3,
        )
    )
    np.testing.assert_array_equal(got[0], x[0] + 100)


def test_nop_and_default_zero():
    base = 1 + N_CONSTS + N_INPUTS
    opcode = [op.NOP]
    image = ([op.NOP], [0], [0], [0], [0] * N_CONSTS, [base, 0, 0])
    x = np.ones((N_INPUTS, BLOCK_BATCH), np.int32)
    got = run_all(*image, x)
    assert (got == 0).all()


def test_validate_image_rejects_forward_reference():
    base = 1 + N_CONSTS + N_INPUTS
    with pytest.raises(ValueError, match="not yet written"):
        validate_image(
            np.array([op.ADD], np.int32),
            np.array([base], np.int32),  # cell 0 reading its own output
            np.array([0], np.int32),
            np.array([0], np.int32),
            np.zeros(N_CONSTS, np.int32),
            np.zeros(N_OUTPUTS, np.int32),
            n_inputs=N_INPUTS,
        )

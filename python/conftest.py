"""Make `from compile import ...` resolve whether pytest runs from the
repo root (`python -m pytest python/tests`, as CI does) or from python/."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

"""DFE functional-unit opcode numbering — the shared ABI with the rust side.

Must stay in sync with `rust/src/dfe/opcodes.rs`. The paper's DFE (§III-A)
supports 32-bit signed integer arithmetic, comparison operators and MUX
nodes; integer division and remainder are explicitly *not* supported, and
neither is floating point — those limits drive the Table I outcomes.
"""

NOP = 0  # output 0
ADD = 1
SUB = 2
MUL = 3  # wrapping i32
MIN = 4
MAX = 5
LT = 6  # comparisons produce 0/1 as i32
GT = 7
LE = 8
GE = 9
EQ = 10
NE = 11
MUX = 12  # sel != 0 ? a : b
AND = 13
OR = 14
XOR = 15
SHL = 16  # shift amount clamped to [0, 31]
SHR = 17  # arithmetic shift right, clamped
PASS = 18  # identity of first operand (routing through an FU)

NUM_OPS = 19

OP_NAMES = {
    NOP: "nop", ADD: "add", SUB: "sub", MUL: "mul", MIN: "min", MAX: "max",
    LT: "lt", GT: "gt", LE: "le", GE: "ge", EQ: "eq", NE: "ne", MUX: "mux",
    AND: "and", OR: "or", XOR: "xor", SHL: "shl", SHR: "shr", PASS: "pass",
}

# L1: Pallas DFE-grid kernel + oracles + shared opcode ABI.
from . import opcodes  # noqa: F401
from .dfe_grid import BLOCK_BATCH, dfe_apply, fu  # noqa: F401
from .ref import py_apply, ref_apply, validate_image  # noqa: F401

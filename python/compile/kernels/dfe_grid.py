"""L1 Pallas kernel: batched execution of a configured DFE grid.

The paper's Data Flow Engine (§III-A) is a pre-programmed FPGA overlay —
a Manhattan grid of functional-unit cells reconfigured at run time to
execute a placed-and-routed DFG. Here the *PJRT executable* plays the role
of the fixed bitstream and the configuration arrives as tensor operands,
so one AOT artifact per grid size serves every DFG the coordinator maps.

Execution model ("execution image" ABI, shared with rust/src/dfe/image.rs):

  value plane slots (one i32 vector of BATCH lanes per slot):
      slot 0                               : constant zero
      slots 1 .. K                         : constant pool
      slots 1+K .. K+NI                    : external inputs
      slots 1+K+NI .. K+NI+N               : cell results, in schedule order

  For cell i (i = 0..N-1):
      r_i = FU(opcode[i], plane[src1[i]], plane[src2[i]], plane[sel[i]])
      plane[1+K+NI+i] = r_i
  Outputs: out[j] = plane[out_sel[j]],  j = 0..NO-1.

The coordinator topologically linearizes the *placed* grid into this
schedule; physical placement only affects the timing/resource model, not
the numerics. src/sel indices must point at already-written slots — the
rust `ExecImage` builder guarantees it, and `ref.py` checks it in tests.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the overlay is an
*integer dataflow* accelerator, so the Pallas design targets the VPU, not
the MXU. The batch dimension is tiled into VMEM via BlockSpec (the analogue
of the paper's PCIe DMA blocks); every cell evaluation is a vectorized
gather + predicated op-select over a full lane block; the per-cell loop is
a fori_loop so the lowered HLO stays small even for the 24x18 grid.

interpret=True everywhere: real-TPU lowering emits Mosaic custom-calls the
CPU PJRT plugin cannot run (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import opcodes as op

# Lane-aligned batch block: one VPU register row of i32 per plane slot.
BLOCK_BATCH = 128


def fu(opcode, a, b, s):
    """Functional unit: predicated evaluation of all ops, select by opcode.

    Computing every candidate and selecting is the standard predicated
    idiom on wide-vector hardware; every op here is a cheap VPU lanewise
    instruction. All values are i32 with wrapping arithmetic (the paper's
    32-bit signed datapath).
    """
    shamt = jnp.clip(b, 0, 31)
    candidates = [
        (op.NOP, jnp.zeros_like(a)),
        (op.ADD, a + b),
        (op.SUB, a - b),
        (op.MUL, a * b),
        (op.MIN, jnp.minimum(a, b)),
        (op.MAX, jnp.maximum(a, b)),
        (op.LT, (a < b).astype(jnp.int32)),
        (op.GT, (a > b).astype(jnp.int32)),
        (op.LE, (a <= b).astype(jnp.int32)),
        (op.GE, (a >= b).astype(jnp.int32)),
        (op.EQ, (a == b).astype(jnp.int32)),
        (op.NE, (a != b).astype(jnp.int32)),
        (op.MUX, jnp.where(s != 0, a, b)),
        (op.AND, a & b),
        (op.OR, a | b),
        (op.XOR, a ^ b),
        (op.SHL, a << shamt),
        (op.SHR, a >> shamt),
        (op.PASS, a),
    ]
    out = jnp.zeros_like(a)
    for code, val in candidates:
        out = jnp.where(opcode == code, val, out)
    return out


def _dfe_kernel(
    opcode_ref, src1_ref, src2_ref, sel_ref, consts_ref, out_sel_ref,
    x_ref, o_ref, *, n_cells: int, n_consts: int, n_inputs: int,
    n_outputs: int,
):
    """One batch block through the whole grid.

    plane is carried functionally through the cell fori_loop (slots-major,
    lanes last) — the whole plane for the largest grid (24x18: 481 slots x
    128 lanes x 4 B ≈ 246 KiB) fits comfortably in VMEM next to the block
    I/O, so no HBM round-trips occur inside a block.
    """
    bb = x_ref.shape[1]
    n_slots = 1 + n_consts + n_inputs + n_cells
    base = 1 + n_consts + n_inputs

    plane = jnp.zeros((n_slots, bb), jnp.int32)
    consts = consts_ref[...]  # [K]
    plane = plane.at[1 : 1 + n_consts, :].set(
        jnp.broadcast_to(consts[:, None], (n_consts, bb))
    )
    plane = plane.at[1 + n_consts : base, :].set(x_ref[...])

    opcode = opcode_ref[...]
    src1 = src1_ref[...]
    src2 = src2_ref[...]
    sel = sel_ref[...]

    def cell(i, plane):
        a = lax.dynamic_index_in_dim(plane, src1[i], axis=0, keepdims=False)
        b = lax.dynamic_index_in_dim(plane, src2[i], axis=0, keepdims=False)
        s = lax.dynamic_index_in_dim(plane, sel[i], axis=0, keepdims=False)
        r = fu(opcode[i], a, b, s)
        return lax.dynamic_update_index_in_dim(plane, r, base + i, axis=0)

    plane = lax.fori_loop(0, n_cells, cell, plane)

    out_sel = out_sel_ref[...]  # [NO]
    o_ref[...] = jnp.take(plane, out_sel, axis=0, mode="clip")


@functools.partial(
    jax.jit, static_argnames=("n_cells", "n_consts", "n_inputs", "n_outputs")
)
def dfe_apply(
    opcode, src1, src2, sel, consts, out_sel, x,
    *, n_cells: int, n_consts: int, n_inputs: int, n_outputs: int,
):
    """Run a configured DFE over a batch of input vectors.

    Args:
      opcode, src1, src2, sel: i32[n_cells] — per-cell configuration.
      consts: i32[n_consts] — constant pool (paper's constant-masked inputs).
      out_sel: i32[n_outputs] — plane slots routed to the outputs.
      x: i32[n_inputs, B] — slot-major batch (B a multiple of BLOCK_BATCH).

    Returns: i32[n_outputs, B].
    """
    n_inputs_x, batch = x.shape
    assert n_inputs_x == n_inputs
    assert batch % BLOCK_BATCH == 0, f"batch {batch} % {BLOCK_BATCH} != 0"

    kernel = functools.partial(
        _dfe_kernel,
        n_cells=n_cells, n_consts=n_consts,
        n_inputs=n_inputs, n_outputs=n_outputs,
    )
    grid = (batch // BLOCK_BATCH,)
    # Config operands are broadcast to every program instance; only the
    # batch axis of x/o is tiled (HBM -> VMEM block schedule).
    cfg1d = lambda n: pl.BlockSpec((n,), lambda b: (0,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            cfg1d(n_cells), cfg1d(n_cells), cfg1d(n_cells), cfg1d(n_cells),
            cfg1d(n_consts), cfg1d(n_outputs),
            pl.BlockSpec((n_inputs, BLOCK_BATCH), lambda b: (0, b)),
        ],
        out_specs=pl.BlockSpec((n_outputs, BLOCK_BATCH), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((n_outputs, batch), jnp.int32),
        interpret=True,
    )(opcode, src1, src2, sel, consts, out_sel, x)

"""Pure-jnp (and pure-python) oracles for the DFE execution-image semantics.

`ref_apply` mirrors kernels/dfe_grid.py exactly but with no Pallas — it is
the correctness ground truth for pytest/hypothesis. `py_apply` is a second,
independently-written scalar-python implementation used to cross-check the
jnp oracle itself (two oracles that agree by construction are worthless;
these two share no code).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

from . import opcodes as op
from .dfe_grid import fu


def ref_apply(opcode, src1, src2, sel, consts, out_sel, x):
    """Vectorized jnp oracle. Same ABI as dfe_grid.dfe_apply (x: [NI, B])."""
    opcode, src1, src2, sel, consts, out_sel, x = (
        jnp.asarray(a, jnp.int32)
        for a in (opcode, src1, src2, sel, consts, out_sel, x)
    )
    n_cells = opcode.shape[0]
    n_consts = consts.shape[0]
    n_inputs, batch = x.shape
    base = 1 + n_consts + n_inputs
    n_slots = base + n_cells

    plane = jnp.zeros((n_slots, batch), jnp.int32)
    plane = plane.at[1 : 1 + n_consts].set(
        jnp.broadcast_to(consts[:, None], (n_consts, batch))
    )
    plane = plane.at[1 + n_consts : base].set(x)

    def cell(i, plane):
        a = plane[src1[i]]
        b = plane[src2[i]]
        s = plane[sel[i]]
        return plane.at[base + i].set(fu(opcode[i], a, b, s))

    plane = lax.fori_loop(0, n_cells, cell, plane)
    return jnp.take(plane, out_sel, axis=0, mode="clip")


def _py_fu(opcode: int, a: int, b: int, s: int) -> int:
    """Scalar FU with explicit 32-bit wrapping — shares no code with fu()."""

    def wrap(v: int) -> int:
        v &= 0xFFFFFFFF
        return v - 0x100000000 if v >= 0x80000000 else v

    if opcode == op.NOP:
        return 0
    if opcode == op.ADD:
        return wrap(a + b)
    if opcode == op.SUB:
        return wrap(a - b)
    if opcode == op.MUL:
        return wrap(a * b)
    if opcode == op.MIN:
        return min(a, b)
    if opcode == op.MAX:
        return max(a, b)
    if opcode == op.LT:
        return int(a < b)
    if opcode == op.GT:
        return int(a > b)
    if opcode == op.LE:
        return int(a <= b)
    if opcode == op.GE:
        return int(a >= b)
    if opcode == op.EQ:
        return int(a == b)
    if opcode == op.NE:
        return int(a != b)
    if opcode == op.MUX:
        return a if s != 0 else b
    if opcode == op.AND:
        return wrap((a & 0xFFFFFFFF) & (b & 0xFFFFFFFF))
    if opcode == op.OR:
        return wrap((a & 0xFFFFFFFF) | (b & 0xFFFFFFFF))
    if opcode == op.XOR:
        return wrap((a & 0xFFFFFFFF) ^ (b & 0xFFFFFFFF))
    if opcode == op.SHL:
        return wrap((a & 0xFFFFFFFF) << max(0, min(b, 31)))
    if opcode == op.SHR:
        return a >> max(0, min(b, 31))  # python >> on signed int is arithmetic
    if opcode == op.PASS:
        return a
    raise ValueError(f"unknown opcode {opcode}")


def py_apply(opcode, src1, src2, sel, consts, out_sel, x):
    """Scalar-python oracle (slow; small batches only)."""
    opcode = np.asarray(opcode)
    src1, src2, sel = np.asarray(src1), np.asarray(src2), np.asarray(sel)
    consts, out_sel = np.asarray(consts), np.asarray(out_sel)
    x = np.asarray(x)
    n_cells = len(opcode)
    n_consts = len(consts)
    n_inputs, batch = x.shape
    base = 1 + n_consts + n_inputs
    n_slots = base + n_cells
    out = np.zeros((len(out_sel), batch), dtype=np.int32)
    for lane in range(batch):
        plane = [0] * n_slots
        for k in range(n_consts):
            plane[1 + k] = int(consts[k])
        for j in range(n_inputs):
            plane[1 + n_consts + j] = int(x[j, lane])
        for i in range(n_cells):
            plane[base + i] = _py_fu(
                int(opcode[i]),
                plane[int(src1[i])],
                plane[int(src2[i])],
                plane[int(sel[i])],
            )
        for j, slot in enumerate(out_sel):
            out[j, lane] = plane[min(int(slot), n_slots - 1)]
    return out


def validate_image(opcode, src1, src2, sel, consts, out_sel, n_inputs: int):
    """Check the topological-schedule invariant the rust builder guarantees:
    every source index of cell i references a slot written before cell i."""
    n_consts = len(consts)
    base = 1 + n_consts + n_inputs
    for i in range(len(opcode)):
        limit = base + i
        for s in (src1[i], src2[i], sel[i]):
            if not (0 <= int(s) < limit):
                raise ValueError(
                    f"cell {i}: source slot {int(s)} not yet written "
                    f"(limit {limit})"
                )

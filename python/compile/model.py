"""L2: the jax compute graph the coordinator AOT-compiles and executes.

For this paper the "model" is the DFE executor itself: one jitted function
per supported grid size, each a thin jax wrapper over the L1 Pallas kernel
(kernels/dfe_grid.py). The configuration — the output of the rust-side
Las-Vegas place & route, linearized into an execution image — is a runtime
*operand*, so one artifact per grid size covers every offloaded DFG, which
is exactly the paper's fixed-bitstream / runtime-reconfiguration split.

Variant table (the ABI contract with rust/src/runtime/):
  every variant shares K=16 constants, NI=32 inputs, NO=8 outputs and a
  batch of 512 lanes; n_cells = rows*cols of the paper's grid sizes.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.dfe_grid import dfe_apply

N_CONSTS = 16
N_INPUTS = 32
N_OUTPUTS = 8
BATCH = 512


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT-compiled DFE executor: a (rows x cols) overlay."""

    rows: int
    cols: int

    @property
    def name(self) -> str:
        return f"dfe_{self.rows}x{self.cols}"

    @property
    def n_cells(self) -> int:
        return self.rows * self.cols

    @property
    def n_slots(self) -> int:
        return 1 + N_CONSTS + N_INPUTS + self.n_cells


# Grid sizes mirror the paper's Table II sweep (plus the small 4x4 used by
# the quickstart/Fig-2 example).
VARIANTS = [
    Variant(4, 4),
    Variant(8, 8),
    Variant(12, 12),
    Variant(15, 15),
    Variant(24, 18),
]


def dfe_fn(variant: Variant):
    """The jax function lowered for `variant` (fixed shapes, ready for AOT)."""

    n = variant.n_cells

    def fn(opcode, src1, src2, sel, consts, out_sel, x):
        out = dfe_apply(
            opcode, src1, src2, sel, consts, out_sel, x,
            n_cells=n, n_consts=N_CONSTS,
            n_inputs=N_INPUTS, n_outputs=N_OUTPUTS,
        )
        return (out,)

    return fn


def example_args(variant: Variant):
    """ShapeDtypeStructs for jax.jit(...).lower(...)."""
    i32 = jnp.int32
    n = variant.n_cells
    return (
        jax.ShapeDtypeStruct((n,), i32),          # opcode
        jax.ShapeDtypeStruct((n,), i32),          # src1
        jax.ShapeDtypeStruct((n,), i32),          # src2
        jax.ShapeDtypeStruct((n,), i32),          # sel
        jax.ShapeDtypeStruct((N_CONSTS,), i32),   # consts
        jax.ShapeDtypeStruct((N_OUTPUTS,), i32),  # out_sel
        jax.ShapeDtypeStruct((N_INPUTS, BATCH), i32),  # x
    )


@functools.lru_cache(maxsize=None)
def jitted(variant: Variant):
    return jax.jit(dfe_fn(variant))

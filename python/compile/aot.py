"""AOT lowering: jax → HLO *text* artifacts the rust runtime loads via PJRT.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate binds) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); python never touches the request
path. Emits one artifact per DFE grid-size variant plus manifest.json with
the ABI metadata rust needs (slot layout, shapes, variant table).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant: model.Variant) -> str:
    lowered = jax.jit(model.dfe_fn(variant)).lower(*model.example_args(variant))
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir", default="../artifacts",
        help="artifact directory (default: ../artifacts, i.e. repo root)",
    )
    # Back-compat with the Makefile's historical single-file target name.
    parser.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = parser.parse_args()

    out_dir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {
        "abi": {
            "n_consts": model.N_CONSTS,
            "n_inputs": model.N_INPUTS,
            "n_outputs": model.N_OUTPUTS,
            "batch": model.BATCH,
            "opcodes": "see python/compile/kernels/opcodes.py == rust/src/dfe/opcodes.rs",
            "plane_layout": "0:zero, 1..K:consts, 1+K..K+NI:inputs, then cells",
            "operands": ["opcode", "src1", "src2", "sel", "consts", "out_sel", "x"],
            "x_layout": "[n_inputs, batch] i32, slot-major",
            "result": "1-tuple of [n_outputs, batch] i32",
        },
        "variants": [],
    }

    for variant in model.VARIANTS:
        text = lower_variant(variant)
        path = out_dir / f"{variant.name}.hlo.txt"
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["variants"].append(
            {
                "name": variant.name,
                "rows": variant.rows,
                "cols": variant.cols,
                "n_cells": variant.n_cells,
                "file": path.name,
                "sha256_16": digest,
            }
        )
        print(f"wrote {path} ({len(text)} chars, sha {digest})")

    manifest_path = out_dir / "manifest.json"
    manifest_path.write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {manifest_path}")

    # The Makefile stamps on a single sentinel file; keep it fresh.
    if args.out:
        pathlib.Path(args.out).write_text(
            (out_dir / f"{model.VARIANTS[0].name}.hlo.txt").read_text()
        )


if __name__ == "__main__":
    main()

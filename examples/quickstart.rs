//! Quickstart (experiment E6): the paper's Fig-2 kernel `C = A + 3B + 1`
//! through the whole stack — profile, SCoP analysis, DFG extraction with
//! unroll 4 (Fig 2C), Las-Vegas place & route onto the overlay, and
//! transparent redirection of the running function to the DFE datapath
//! (the AOT Pallas/PJRT artifact when `artifacts/` exists, otherwise the
//! rust functional simulator).
//!
//! Run: `cargo run --release --example quickstart [-- --n 4096 --seed 7]`

use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::Ty;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::offload::{OffloadManager, OffloadParams};
use tlo::runtime::PjrtRuntime;
use tlo::util::cli::Args;

fn fig2_module() -> Module {
    let mut m = Module::new();
    let mut b = FuncBuilder::new(
        "fig2",
        &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
    );
    let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let av = b.load(Ty::I32, a, i);
        let bv = b.load(Ty::I32, bb, i);
        let c3 = b.const_i32(3);
        let t = b.mul(bv, c3);
        let s = b.add(av, t);
        let c1 = b.const_i32(1);
        let r = b.add(s, c1);
        b.store(Ty::I32, c, i, r);
    });
    m.add(b.ret(None));
    m
}

fn main() -> tlo::util::err::Result<()> {
    let args = Args::from_env(&["n", "seed", "unroll"]);
    let n = args.get_usize("n", 4096);
    let unroll = args.get_usize("unroll", 4);

    let mut engine = Engine::new(fig2_module())?;
    let mut mem = Memory::new();
    let a: Vec<i32> = (0..n as i32).map(|i| i * 7 - 1000).collect();
    let b: Vec<i32> = (0..n as i32).map(|i| 13 - i).collect();
    let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
    let hc = mem.alloc_i32(n);
    let call_args = [Val::P(hc), Val::P(ha), Val::P(hb), Val::I(n as i32)];

    // 1. software run (profiles the function).
    engine.call("fig2", &mut mem, &call_args)?;
    let func = engine.func_index("fig2").unwrap();
    let prof = engine.profile(func);
    println!(
        "software: {} abstract cycles, {} memory accesses",
        prof.counters.cycles, prof.counters.mem_accesses
    );

    // 2. offload.
    let mut mgr = OffloadManager::new(OffloadParams {
        unroll,
        min_dfg_nodes: 4,
        seed: args.get_u64("seed", 0xD0E),
        ..Default::default()
    });
    let mut pjrt = PjrtRuntime::load_default().ok();
    match &pjrt {
        Some(rt) => println!("DFE datapath: PJRT ({})", rt.platform()),
        None => println!("DFE datapath: rust functional simulator (run `make artifacts`)"),
    }
    let rec = mgr
        .try_offload(&mut engine, func, pjrt.as_mut())
        .map_err(|e| tlo::anyhow!("offload rejected: {e}"))?;
    println!(
        "offloaded '{}': DFG {} in / {} out / {} calc ({} nodes, unroll x{})",
        rec.name, rec.inputs, rec.outputs, rec.calc, rec.dfg_nodes, unroll
    );
    if let Some(ps) = rec.par_stats {
        println!(
            "place&route: {} placements, {} route calls, {} retries, {} restarts, {}",
            ps.placements,
            ps.route_calls,
            ps.pos_retries,
            ps.restarts,
            tlo::util::fmt_duration(ps.elapsed)
        );
    }

    // 3. run on the DFE and check every element.
    mem.i32s_mut(hc).fill(0);
    engine.call("fig2", &mut mem, &call_args)?;
    for i in 0..n {
        let want = a[i].wrapping_add(b[i].wrapping_mul(3)).wrapping_add(1);
        assert_eq!(mem.i32s(hc)[i], want, "mismatch at {i}");
    }
    println!("numerics: all {n} elements match C = A + 3B + 1");

    let st = mgr.state(func).unwrap();
    let st = st.borrow();
    println!(
        "virtual offload time: {} ({} elements, {} remainder)",
        tlo::util::fmt_duration(st.virtual_offload),
        st.last_report.elements,
        st.last_report.remainder_elements,
    );
    println!("\n== phase timeline ==\n{}", mgr.tracer.borrow().render_timeline());
    Ok(())
}

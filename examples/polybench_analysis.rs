//! Table I regenerator (experiment E1): run the analysis phase over the
//! PolyBench suite and print detection / offloadability / DFG statistics /
//! analysis time next to the paper's numbers.
//!
//! Run: `cargo run --release --example polybench_analysis`

use tlo::analysis::scop::analyze_function;
use tlo::dfg::extract::extract;
use tlo::workloads::polybench::suite;

fn main() {
    println!(
        "{:<16} {:<26} {:>14} {:>12} | {:<24} {:>12} {:>10}",
        "benchmark", "DFE off-load (ours)", "in/out/calc", "analysis",
        "paper off-load", "paper nodes", "paper us"
    );
    println!("{}", "-".repeat(122));
    let mut detected = 0;
    let mut total = 0;
    for k in suite() {
        total += 1;
        let t0 = std::time::Instant::now();
        let an = analyze_function(&k.func);
        // Merge every extractable innermost SCoP (the paper merges the
        // extracted DFGs before P&R).
        let mut ins = 0;
        let mut outs = 0;
        let mut calc = 0;
        let mut ok = false;
        let mut reject: Option<String> = an.rejects.first().map(|r| r.label().to_string());
        for scop in &an.scops {
            match extract(&k.func, scop, k.unroll) {
                Ok(off) => {
                    let st = off.dfg.stats();
                    ins += st.inputs;
                    outs += st.outputs;
                    calc += st.calc;
                    ok = true;
                }
                Err(e) => reject = Some(e.label().to_string()),
            }
        }
        let elapsed = t0.elapsed() + an.elapsed;
        if !an.scops.is_empty() || ok {
            detected += 1;
        }
        let (ours, nodes) = if ok {
            ("Yes".to_string(), format!("{ins}/{outs}/{calc}"))
        } else {
            (reject.unwrap_or_else(|| "no SCoP".into()), String::new())
        };
        println!(
            "{:<16} {:<26} {:>14} {:>12} | {:<24} {:>12} {:>10}",
            k.name,
            ours,
            nodes,
            format!("{}us", elapsed.as_micros()),
            k.paper.offload,
            k.paper.nodes,
            if k.paper.analysis_us > 0 { k.paper.analysis_us.to_string() } else { "-".into() },
        );
    }
    println!(
        "\nSCoPs detected in {detected}/{total} kernels (paper: 21/25 detected, \
         2 lost to MUX handling, 2 with no SCoP)"
    );
}

//! Listing 1 / Fig 4 (experiment E7): code with unavoidable dynamic
//! branches is if-converted to CMP + MUX nodes and executed directly on
//! the DFE fabric, with the rollback monitor left armed.
//!
//! Run: `cargo run --release --example branchy [-- --n 8192]`

use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::{CmpPred, Term, Ty};
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};
use tlo::offload::{OffloadManager, OffloadParams};
use tlo::runtime::PjrtRuntime;
use tlo::util::cli::Args;

/// Listing 1, authored with a *real* diamond (not a pre-lowered select):
/// if (A[i] > B[i]) C[i] = A[i]+3B[i]+1 else C[i] = A[i]-5B[i]-2
fn listing1_module() -> Module {
    let mut m = Module::new();
    let mut b = FuncBuilder::new(
        "listing1",
        &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
    );
    let (cp, a, bp, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let av = b.load(Ty::I32, a, i);
        let bv = b.load(Ty::I32, bp, i);
        let c = b.cmp(CmpPred::Gt, av, bv);
        let r = b.fresh();
        let tb = b.new_block();
        let fb = b.new_block();
        let join = b.new_block();
        b.terminate(Term::CondBr { c, t: tb, f: fb });
        b.switch_to(tb);
        let c3 = b.const_i32(3);
        let t0 = b.mul(bv, c3);
        let t1 = b.add(av, t0);
        let one = b.const_i32(1);
        let t2 = b.add(t1, one);
        b.mov_into(r, t2);
        b.terminate(Term::Br(join));
        b.switch_to(fb);
        let c5 = b.const_i32(5);
        let e0 = b.mul(bv, c5);
        let e1 = b.sub(av, e0);
        let two = b.const_i32(2);
        let e2 = b.sub(e1, two);
        b.mov_into(r, e2);
        b.terminate(Term::Br(join));
        b.switch_to(join);
        b.store(Ty::I32, cp, i, r);
    });
    m.add(b.ret(None));
    m
}

fn main() -> tlo::util::err::Result<()> {
    let args = Args::from_env(&["n"]);
    let n = args.get_usize("n", 8192);

    let mut engine = Engine::new(listing1_module())?;
    let mut mem = Memory::new();
    let a: Vec<i32> = (0..n as i32).map(|i| (i * 37) % 211 - 100).collect();
    let b: Vec<i32> = (0..n as i32).map(|i| (i * 53) % 199 - 100).collect();
    let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
    let hc = mem.alloc_i32(n);
    let call_args = [Val::P(hc), Val::P(ha), Val::P(hb), Val::I(n as i32)];

    engine.call("listing1", &mut mem, &call_args)?;
    let func = engine.func_index("listing1").unwrap();

    let mut mgr = OffloadManager::new(OffloadParams {
        min_dfg_nodes: 4,
        unroll: 2,
        ..Default::default()
    });
    let mut pjrt = PjrtRuntime::load_default().ok();
    let rec = mgr
        .try_offload(&mut engine, func, pjrt.as_mut())
        .map_err(|e| tlo::anyhow!("offload rejected: {e}"))?;
    println!(
        "if-converted DFG: {} in / {} out / {} calc (CMP + MUX in fabric, Fig 4)",
        rec.inputs, rec.outputs, rec.calc
    );

    mem.i32s_mut(hc).fill(0);
    engine.call("listing1", &mut mem, &call_args)?;
    for i in 0..n {
        let want = if a[i] > b[i] { a[i] + 3 * b[i] + 1 } else { a[i] - 5 * b[i] - 2 };
        assert_eq!(mem.i32s(hc)[i], want, "element {i}");
    }
    println!("numerics: both branch paths correct across {n} elements");

    // Rollback monitor verdict after a few more invocations.
    for _ in 0..4 {
        engine.call("listing1", &mut mem, &call_args)?;
    }
    let rolled = mgr.check_rollback(&mut engine);
    println!(
        "rollback monitor: {}",
        if rolled.is_empty() { "offload kept" } else { "rolled back to software (transfer-bound)" }
    );
    Ok(())
}

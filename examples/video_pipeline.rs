//! §IV-C prototype case study (experiments E3 + E4): the video-processing
//! pipeline. A synthetic frame stream is convolved per frame; after a few
//! frames the runtime offloads the convolution (17 in / 1 out / 16 calc
//! DFG, like the paper). The example reports the Fig-6 phase timeline and
//! the software-vs-offloaded frame rate — the paper's honest headline is
//! that the offloaded path is *slower* (31 vs 83 fps) because the naive
//! tagged PCIe protocol dominates; `--riffa` switches to the packed
//! protocol ablation (A1) to show the projected gain.
//!
//! Run: `cargo run --release --example video_pipeline [-- --frames 32 --riffa]`

use std::time::Duration;

use tlo::jit::engine::Engine;
use tlo::jit::interp::Memory;
use tlo::offload::{OffloadManager, OffloadParams};
use tlo::runtime::PjrtRuntime;
use tlo::trace::Phase;
use tlo::transport::PcieParams;
use tlo::util::cli::Args;
use tlo::util::fmt_duration;
use tlo::workloads::video::{
    alloc_pipeline, conv_args, video_module, FrameSource, DECODE_MS, FRAME_H, FRAME_W,
};

fn main() -> tlo::util::err::Result<()> {
    let args = Args::from_env(&["frames", "seed"]);
    let frames = args.get_usize("frames", 24);
    let riffa = args.flag("riffa");

    let mut engine = Engine::new(video_module())?;
    let mut mem = Memory::new();
    let (out, inp, coef) = alloc_pipeline(&mut mem);
    let mut src = FrameSource::new();
    let mut frame = vec![0i32; FRAME_W * FRAME_H];
    let func = engine.func_index("conv").unwrap();
    let decode = Duration::from_secs_f64(DECODE_MS * 1e-3);

    // ---- software phase: run a few frames, measure the baseline ----
    let warm = 4.min(frames);
    for _ in 0..warm {
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        engine.call("conv", &mut mem, &conv_args(out, inp, coef))?;
    }
    let prof = engine.profile(func);
    let sw_conv =
        Duration::from_secs_f64(1e-9 * prof.counters.cycles as f64 / warm.max(1) as f64);
    let sw_frame = decode + sw_conv;
    let sw_fps = 1.0 / sw_frame.as_secs_f64();
    println!(
        "software: conv {} / frame  (+{DECODE_MS} ms decode)  -> {:.1} fps",
        fmt_duration(sw_conv),
        sw_fps
    );

    // ---- the runtime decides to offload (paper: "after running the
    //      application for a few seconds") ----
    let mut params = OffloadParams {
        min_dfg_nodes: 8,
        unroll: 1,
        seed: args.get_u64("seed", 42),
        ..Default::default()
    };
    if riffa {
        params.pcie = PcieParams::riffa_like();
    }
    let mut mgr = OffloadManager::new(params);
    let mut pjrt = PjrtRuntime::load_default().ok();
    println!(
        "DFE datapath: {}",
        match &pjrt {
            Some(rt) => format!("PJRT ({})", rt.platform()),
            None => "rust functional simulator".into(),
        }
    );
    let rec = mgr
        .try_offload(&mut engine, func, pjrt.as_mut())
        .map_err(|e| tlo::anyhow!("offload rejected: {e}"))?;
    println!(
        "offloaded conv: DFG {} in / {} out / {} calc (paper: 17/1/16)",
        rec.inputs, rec.outputs, rec.calc
    );

    // ---- offloaded frames ----
    let mut check = Vec::new();
    for _ in warm..frames {
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        mgr.tracer.borrow_mut().simulated(Phase::HostWork, decode);
        engine.call("conv", &mut mem, &conv_args(out, inp, coef))?;
        check.push((frame.clone(), mem.i32s(out).to_vec()));
    }
    // Verify numerics on the last frame.
    if let Some((f, got)) = check.last() {
        let want = tlo::workloads::video::conv_reference(
            f,
            &tlo::workloads::video::COEF,
            FRAME_W,
            FRAME_H,
        );
        assert_eq!(got, &want, "offloaded convolution numerics");
        println!("numerics: offloaded frames match the host reference");
    }

    let st = mgr.state(func).unwrap();
    let st = st.borrow();
    let n_off = st.invocations.max(1);
    let off_frame = decode + st.virtual_offload / n_off as u32;
    let off_fps = 1.0 / off_frame.as_secs_f64();
    println!(
        "offloaded: {} / frame -> {:.1} fps   (paper: 31 fps offloaded vs 83 fps software)",
        fmt_duration(off_frame),
        off_fps
    );
    println!(
        "PCIe: {} transfers, {:.1} MB payload, {:.1} MB wire ({}), effective {:.1} MB/s",
        mgr.pcie.borrow().transfers,
        mgr.pcie.borrow().total_payload as f64 / 1e6,
        mgr.pcie.borrow().total_wire as f64 / 1e6,
        if riffa { "packed/RIFFA-like" } else { "tagged 128b/32b, 75% overhead" },
        mgr.pcie.borrow().effective_rate() / 1e6,
    );
    println!("\n== Fig-6 phase timeline ==\n{}", mgr.tracer.borrow().render_timeline());
    println!(
        "summary: software {:.1} fps vs offloaded {:.1} fps ({})",
        sw_fps,
        off_fps,
        if off_fps < sw_fps {
            "transfer-bound, as in the paper"
        } else {
            "offload wins with the packed protocol"
        }
    );
    Ok(())
}

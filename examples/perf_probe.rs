//! §Perf probe: interpreter throughput measurement used for the
//! EXPERIMENTS.md §Perf baseline/after comparison.
use tlo::ir::func::{FuncBuilder, Module};
use tlo::ir::instr::Ty;
use tlo::jit::engine::Engine;
use tlo::jit::interp::{Memory, Val};

fn main() {
    let mut m = Module::new();
    let mut b = FuncBuilder::new("k", &[("A", Ty::Ptr), ("n", Ty::I32)]);
    let (a, n) = (b.param(0), b.param(1));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let v = b.load(Ty::I32, a, i);
        let w = b.mul(v, v);
        let x = b.add(w, v);
        b.store(Ty::I32, a, i, x);
    });
    m.add(b.ret(None));
    let mut engine = Engine::new(m).unwrap();
    let mut mem = Memory::new();
    let n = 100_000usize;
    let h = mem.alloc_i32(n);
    let t0 = std::time::Instant::now();
    for _ in 0..10 {
        engine.call("k", &mut mem, &[Val::P(h), Val::I(n as i32)]).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let f = engine.func_index("k").unwrap();
    let insts = engine.profile(f).counters.insts as f64;
    println!("{:.1} M bytecode ops/s", insts / dt / 1e6);
}
